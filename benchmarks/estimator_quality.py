"""CE quality — GBDT i-/s-Estimator held-out accuracy, the end-to-end
plan-quality gap of data-driven FCO vs the analytic oracle (§3.2), and
the heterogeneity acceptance record (BENCH_estimator.json).

The JSON record carries the hard CI gates for the hetero-aware learned
estimator (``check_regression.py --kind estimator``):

* per preset (``mixed_fast_slow``, ``stepped``), the mean
  plan-cost/oracle ratio of the hetero-trained GBDT over the
  model x node-count evaluation grid must stay within 5% of the analytic
  oracle (``hetero_within_5pct``) and strictly below the
  homogeneous-trained GBDT's ratio (``hetero_beats_hom``);
* online calibration must cut the predicted-period error at least 2x on
  the seeded skewed-occupancy scenario (``reduced_2x``).

Timings (``train_us`` etc.) are advisory.  Everything is seeded, so the
record is deterministic for a given budget: the per-push CI job runs the
smoke budget against the committed smoke baseline; nightly runs
``--full`` (3x traces, 2x trees) against the same flags.
"""
from __future__ import annotations

import json
import sys

import numpy as np

from repro.core import AnalyticEstimator, Testbed
from repro.core.dpp import plan_search
from repro.core.plan import plan_cost
from repro.configs.edge_models import mobilenet_v1, resnet18
from repro.sim import (TraceConfig, generate_i_traces, hetero_trace_config,
                       train_estimators)

from .common import emit, json_arg, time_call

#: training budgets: (n_samples, trees, depth, hetero_fraction)
SMOKE_BUDGET = (20_000, 60, 7, 0.7)
FULL_BUDGET = (60_000, 120, 7, 0.7)
EVAL_NODES = (4, 5, 6)
PRESETS = ("mixed_fast_slow", "stepped")


def run(n_samples: int = 12_000, trees: int = 60) -> None:
    """Homogeneous CE quality (the historical stdout benchmark)."""
    cfg = TraceConfig(n_samples=n_samples, seed=0)
    us, est = time_call(lambda: train_estimators(
        cfg, gbdt_kwargs=dict(n_estimators=trees, max_depth=7)), repeats=1)

    held = TraceConfig(n_samples=2000, seed=99)
    xi, yi = generate_i_traces(held)
    rel = np.exp(np.abs(est.i_model.predict(xi) - yi)) - 1
    emit("ce/i-estimator", us,
         f"samples={n_samples};trees={trees};"
         f"median_rel_err={np.median(rel) * 100:.1f}%;"
         f"p90_rel_err={np.percentile(rel, 90) * 100:.1f}%")

    g = mobilenet_v1()
    tb = Testbed(nodes=4, bandwidth_gbps=1.0)
    us2, plan = time_call(lambda: plan_search(g, est, tb).plan, repeats=1)
    true_cost = plan_cost(g, plan, AnalyticEstimator(), tb)
    opt = plan_search(g, AnalyticEstimator(), tb).cost
    emit("ce/plan-gap", us2,
         f"gbdt_plan_true_cost={true_cost * 1e3:.2f}ms;"
         f"oracle_optimal={opt * 1e3:.2f}ms;"
         f"gap={(true_cost / opt - 1) * 100:.1f}%")


def _preset_quality(het, hom, graphs) -> dict:
    """Mean plan-cost/oracle ratios of both estimators per preset."""
    from repro.cluster import (CLUSTER_PRESETS, ClusterAnalyticEstimator,
                               ClusterGBDTEstimator, cluster_plan_search)
    out = {}
    for preset in PRESETS:
        het_r, hom_r, cells = [], [], {}
        for gname, g in graphs:
            for n in EVAL_NODES:
                cl = CLUSTER_PRESETS[preset](n)
                tb = cl.compat_testbed()
                oracle = cluster_plan_search(g, cl)
                ae = ClusterAnalyticEstimator(cl)
                ce = ClusterGBDTEstimator(het, cl)
                h = plan_cost(g, cluster_plan_search(
                    g, cl, estimator=ce).plan, ae, tb) / oracle.cost
                m = plan_cost(g, plan_search(g, hom, tb).plan, ae,
                              tb) / oracle.cost
                het_r.append(h)
                hom_r.append(m)
                cells[f"{gname}/n{n}"] = {"hetero_ratio": h,
                                          "hom_ratio": m}
        het_mean = float(np.mean(het_r))
        hom_mean = float(np.mean(hom_r))
        out[preset] = {
            "hetero_oracle_ratio": het_mean,
            "hom_oracle_ratio": hom_mean,
            "hetero_within_5pct": bool(het_mean <= 1.05),
            "hetero_beats_hom": bool(het_mean < hom_mean),
            "cells": cells,
        }
    return out


def _calibration_record() -> dict:
    """Seeded skewed-occupancy scenario: two devices run 1.7x slower and
    links 1.3x slower than the physics says; a handful of folded
    measurements must cut the predicted-period error >= 2x."""
    from repro.cluster import (OnlineCalibrator, cluster_plan_search,
                               mixed_fast_slow)
    cl = mixed_fast_slow(4)
    g = mobilenet_v1(96)
    plan = cluster_plan_search(g, cl).plan
    cal = OnlineCalibrator(cl, decay=0.6)
    dev, link = cal.predicted_occupancy(g, plan)
    skew = np.where(np.arange(cl.n) == int(np.argmax(dev)), 1.7, 1.0)
    true_dev = float(np.max(dev * skew))
    true_link = float(np.max(link)) * 1.3
    true_period = max(true_dev, true_link)

    class _Meas:
        dev_occupancy_s = true_dev
        link_occupancy_s = true_link
        period_s = true_period
        failures = 0

    errs = [abs(cal.predict_period(g, plan) - true_period) / true_period]
    for _ in range(6):
        cal.observe(g, plan, _Meas())
        errs.append(abs(cal.predict_period(g, plan) - true_period)
                    / true_period)
    reduction = errs[0] / max(errs[-1], 1e-15)
    return {
        "initial_rel_err": errs[0],
        "final_rel_err": errs[-1],
        "error_trajectory": errs,
        "reduction": reduction,
        "reduced_2x": bool(reduction >= 2.0),
    }


def quality_record(full: bool = False) -> dict:
    n_samples, trees, depth, fraction = FULL_BUDGET if full else SMOKE_BUDGET
    kw = dict(n_estimators=trees, max_depth=depth)
    us_het, het = time_call(lambda: train_estimators(
        hetero_trace_config(n_samples=n_samples, seed=0,
                            hetero_fraction=fraction),
        gbdt_kwargs=kw), repeats=1)
    us_hom, hom = time_call(lambda: train_estimators(
        TraceConfig(n_samples=n_samples, seed=0), gbdt_kwargs=kw),
        repeats=1)
    graphs = [("mobilenet", mobilenet_v1(96)), ("resnet18", resnet18(96))]
    presets = _preset_quality(het, hom, graphs)
    cal = _calibration_record()
    for preset, rec in presets.items():
        emit(f"ce/hetero-{preset}", us_het,
             f"hetero_ratio={rec['hetero_oracle_ratio']:.4f};"
             f"hom_ratio={rec['hom_oracle_ratio']:.4f};"
             f"beats={rec['hetero_beats_hom']};"
             f"within5={rec['hetero_within_5pct']}")
    emit("ce/calibration", 0.0,
         f"err {cal['initial_rel_err']:.3f}->{cal['final_rel_err']:.3f};"
         f"reduction={cal['reduction']:.1f}x")
    return {
        "budget": {"n_samples": n_samples, "trees": trees, "depth": depth,
                   "hetero_fraction": fraction,
                   "mode": "full" if full else "smoke"},
        "presets": presets,
        "calibration": cal,
        "train_hetero_us": us_het,
        "train_hom_us": us_hom,
        "noise_note": "train_*_us timings are advisory on shared CI "
                      "runners; the quality flags are the gate",
    }


if __name__ == "__main__":
    json_path = json_arg(sys.argv[1:], default="BENCH_estimator.json")
    if json_path is not None:
        rec = quality_record(full="--full" in sys.argv[1:])
        with open(json_path, "w") as f:
            json.dump(rec, f, indent=1, sort_keys=True)
        print(f"# wrote {json_path}")
    else:
        run()
