"""Partition-scheme geometry: shard sizes, halos, balance, comm volumes.

Implements the four schemes of Fig. 1 — One-dim InH / InW / OutC and 2D-grid —
plus the T/NT boundary semantics of §2.3.  Everything here is exact integer
geometry (no estimation); the cost model in ``cost.py`` turns these byte/FLOP
counts into times for a given testbed.

The scalar helpers each have a ``*_batch`` ufunc form operating on stacked
feature columns (one row per query).  The batch forms replicate the scalar
float operation *order*, so results are bit-identical — the planner's
batched cost tables must agree exactly with the scalar reference path.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import List, Sequence, Tuple

import numpy as np

from .graph import ConvT, LayerSpec


class Scheme(enum.IntEnum):
    INH = 0      # split input/output feature-map height
    INW = 1      # split width
    OUTC = 2     # split output channels
    GRID2D = 3   # split height x width grid

    @property
    def spatial(self) -> bool:
        return self in (Scheme.INH, Scheme.INW, Scheme.GRID2D)


class Mode(enum.IntEnum):
    T = 0    # transmit boundary/re-layout data after this layer
    NT = 1   # no transmission; fuse via redundant halo compute


ALL_SCHEMES: Tuple[Scheme, ...] = (Scheme.INH, Scheme.INW, Scheme.OUTC,
                                   Scheme.GRID2D)


def split_sizes(total: int, parts: int) -> List[int]:
    """Balanced 1-D split (ceil for the first ``total % parts`` shards)."""
    q, r = divmod(total, parts)
    return [q + (1 if i < r else 0) for i in range(parts)]


def weighted_split_sizes(total: int, weights: Sequence[float]) -> List[int]:
    """Capability-proportional integer split (largest-remainder method).

    Device ``d`` receives ``round(total * w_d / sum(w))`` units, with the
    leftover units after flooring handed to the largest fractional parts
    (ties broken toward lower device index).  Uniform weights reduce
    *exactly* to :func:`split_sizes` — every fractional part ties, so the
    first ``total % parts`` shards take the ceil, shard for shard — which
    is what keeps homogeneous ``ClusterSpec`` costs bit-identical to the
    historical ``Testbed`` path.  A zero weight yields a zero-size shard.
    """
    ws = [float(w) for w in weights]
    if any(w < 0.0 for w in ws):
        raise ValueError(f"negative capability weight in {ws}")
    s = sum(ws)
    if s <= 0.0:
        raise ValueError("capability weights must sum to a positive value")
    ideal = [total * w / s for w in ws]
    base = [int(math.floor(x)) for x in ideal]
    rem = total - sum(base)
    order = sorted(range(len(ws)), key=lambda i: (base[i] - ideal[i], i))
    for i in order[:rem]:
        base[i] += 1
    return base


def grid_dims(nodes: int) -> Tuple[int, int]:
    """2D-grid cell layout.  4 nodes -> 2x2.  Non-square node counts get a
    ceil(sqrt) grid whose cells are assigned round-robin, reproducing the
    paper's observation that 3 nodes leave one node with 2x the work."""
    gh = int(math.ceil(math.sqrt(nodes)))
    gw = int(math.ceil(nodes / gh))
    return gh, gw


@dataclasses.dataclass(frozen=True)
class ShardWork:
    """Per-node workload of one layer under one scheme."""

    flops_per_node: Tuple[float, ...]   # straggler = max(...)
    out_bytes_per_node: Tuple[float, ...]

    @property
    def straggler_flops(self) -> float:
        return max(self.flops_per_node)

    @property
    def imbalance(self) -> float:
        mx = max(self.flops_per_node)
        avg = sum(self.flops_per_node) / len(self.flops_per_node)
        return mx / max(avg, 1.0)


DTYPE_BYTES = 4.0  # fp32 feature maps (TMS320C6678 is a float DSP)


def _conv_row_flops(layer: LayerSpec, out_rows: int, out_cols: int,
                    out_ch: int) -> float:
    """FLOPs to produce an ``out_rows x out_cols x out_ch`` output region."""
    if layer.conv_t in (ConvT.CONV, ConvT.POINTWISE):
        per = 2.0 * layer.in_c * layer.k * layer.k
    elif layer.conv_t == ConvT.DWCONV:
        per = 2.0 * layer.k * layer.k
    elif layer.conv_t == ConvT.POOL:
        per = 1.0 * layer.k * layer.k
    elif layer.conv_t == ConvT.FC:
        # FC: "rows" = sequence positions, cols = 1
        per = 2.0 * layer.in_c
    elif layer.conv_t in (ConvT.ATTN, ConvT.FFN):
        # projection MACs; score/AV (ATTN) and hidden (FFN) work is linear
        # in the owned output region and rides in extra_flop_factor
        per = 2.0 * layer.in_c
    elif layer.conv_t == ConvT.ADD:
        per = float(max(1, layer.fan_in - 1))   # (fan_in - 1) adds per elem
    else:  # CONCAT: copy cost
        per = 1.0
    return per * out_rows * out_cols * out_ch * layer.extra_flop_factor


def shard_work(layer: LayerSpec, scheme: Scheme, nodes: int,
               extra_halo: int = 0) -> ShardWork:
    """Workload of ``layer`` under ``scheme`` on ``nodes`` devices.

    ``extra_halo`` = extra output rows (per side) this layer must additionally
    compute because later layers are NT-fused after it (see
    ``graph.halo_growth``).  Only spatial schemes accept a nonzero halo; OutC
    cannot run in NT mode (its next layer needs the full input).
    """
    oh, ow, oc = layer.out_h, layer.out_w, layer.out_c
    if extra_halo and not scheme.spatial:
        raise ValueError("NT halo is undefined for OutC partition")

    flops: List[float] = []
    obytes: List[float] = []
    if scheme == Scheme.INH:
        for rows in split_sizes(oh, nodes):
            r = min(rows + 2 * extra_halo, oh)
            flops.append(_conv_row_flops(layer, r, ow, oc))
            obytes.append(r * ow * oc * DTYPE_BYTES)
    elif scheme == Scheme.INW:
        for cols in split_sizes(ow, nodes):
            c = min(cols + 2 * extra_halo, ow)
            flops.append(_conv_row_flops(layer, oh, c, oc))
            obytes.append(oh * c * oc * DTYPE_BYTES)
    elif scheme == Scheme.OUTC:
        if layer.heads:
            # ATTN: shard at head granularity (a head's channels never split)
            per_head = oc // layer.heads
            chs = [h * per_head for h in split_sizes(layer.heads, nodes)]
        else:
            chs = split_sizes(oc, nodes)
        for ch in chs:
            flops.append(_conv_row_flops(layer, oh, ow, ch))
            obytes.append(oh * ow * ch * DTYPE_BYTES)
    elif scheme == Scheme.GRID2D:
        gh, gw = grid_dims(nodes)
        rsz, csz = split_sizes(oh, gh), split_sizes(ow, gw)
        cells = [(r, c) for r in rsz for c in csz]
        per_node_f = [0.0] * nodes
        per_node_b = [0.0] * nodes
        for idx, (r, c) in enumerate(cells):
            node = idx % nodes
            rr = min(r + 2 * extra_halo, oh)
            cc = min(c + 2 * extra_halo, ow)
            per_node_f[node] += _conv_row_flops(layer, rr, cc, oc)
            per_node_b[node] += rr * cc * oc * DTYPE_BYTES
        flops, obytes = per_node_f, per_node_b
    else:  # pragma: no cover
        raise ValueError(scheme)
    return ShardWork(tuple(flops), tuple(obytes))


def hetero_shard_work(layer: LayerSpec, scheme: Scheme,
                      weights: Sequence[float],
                      extra_halo: int = 0) -> ShardWork:
    """Workload of ``layer`` under ``scheme`` with capability-weighted shard
    fractions: device ``d`` owns a :func:`weighted_split_sizes` share of the
    split axis instead of a balanced one.

    Mirrors :func:`shard_work` expression for expression (including the
    ``min(extent + 2*halo, full)`` NT-halo clip), so uniform weights give
    bit-identical per-node numbers.  GRID2D keeps the balanced round-robin
    cell grid — the 2-D cell layout has no natural 1-D weighting — so
    capability only enters GRID2D through the per-device *speeds* the cost
    model divides by (skewed clusters simply stop choosing it).
    """
    nodes = len(weights)
    oh, ow, oc = layer.out_h, layer.out_w, layer.out_c
    if extra_halo and not scheme.spatial:
        raise ValueError("NT halo is undefined for OutC partition")
    if scheme == Scheme.GRID2D:
        return shard_work(layer, scheme, nodes, extra_halo=extra_halo)

    flops: List[float] = []
    obytes: List[float] = []
    if scheme == Scheme.INH:
        for rows in weighted_split_sizes(oh, weights):
            r = min(rows + 2 * extra_halo, oh)
            flops.append(_conv_row_flops(layer, r, ow, oc))
            obytes.append(r * ow * oc * DTYPE_BYTES)
    elif scheme == Scheme.INW:
        for cols in weighted_split_sizes(ow, weights):
            c = min(cols + 2 * extra_halo, ow)
            flops.append(_conv_row_flops(layer, oh, c, oc))
            obytes.append(oh * c * oc * DTYPE_BYTES)
    elif scheme == Scheme.OUTC:
        if layer.heads:
            per_head = oc // layer.heads
            chs = [h * per_head
                   for h in weighted_split_sizes(layer.heads, weights)]
        else:
            chs = weighted_split_sizes(oc, weights)
        for ch in chs:
            flops.append(_conv_row_flops(layer, oh, ow, ch))
            obytes.append(oh * ow * ch * DTYPE_BYTES)
    else:  # pragma: no cover
        raise ValueError(scheme)
    return ShardWork(tuple(flops), tuple(obytes))


def min_shard_extent(layer: LayerSpec, scheme: Scheme, nodes: int) -> int:
    """Smallest spatial extent any node owns under ``scheme`` — the bound at
    which an NT halo degenerates into full replication."""
    if scheme == Scheme.INH:
        return min(split_sizes(layer.out_h, nodes))
    if scheme == Scheme.INW:
        return min(split_sizes(layer.out_w, nodes))
    if scheme == Scheme.GRID2D:
        gh, gw = grid_dims(nodes)
        return min(min(split_sizes(layer.out_h, gh)),
                   min(split_sizes(layer.out_w, gw)))
    return 1


# ---------------------------------------------------------------------------
# Communication volumes (bytes) for T-mode boundaries.
# ---------------------------------------------------------------------------

def boundary_bytes_same_scheme(layer: LayerSpec, nxt: LayerSpec,
                               scheme: Scheme, nodes: int) -> float:
    """T-mode halo exchange when this layer and the next share a spatial
    scheme: each interior boundary moves (K_next - 1) rows/cols of the output
    feature map, both directions.  Returns the *per-busiest-node* byte count
    (what the latency-dominant node sends+receives)."""
    halo = max(nxt.k - 1, 0)
    if halo == 0 or nodes <= 1:
        return 0.0   # K=1 (FC/ADD/CONCAT/pointwise) or a single node: no halo
    oh, ow, oc = layer.out_h, layer.out_w, layer.out_c
    if scheme == Scheme.INH:
        return 2.0 * halo * ow * oc * DTYPE_BYTES        # two neighbours
    if scheme == Scheme.INW:
        return 2.0 * halo * oh * oc * DTYPE_BYTES
    if scheme == Scheme.GRID2D:
        gh, gw = grid_dims(nodes)
        rows = math.ceil(oh / gh)
        cols = math.ceil(ow / gw)
        # up/down + left/right + corners
        return 2.0 * halo * (cols + rows + halo) * oc * DTYPE_BYTES
    raise ValueError(scheme)


# ---------------------------------------------------------------------------
# Batched (ufunc) forms.  One row per query; integer columns are int64
# arrays, float columns float64.  Float expressions copy the scalar
# operation order verbatim so results are bit-identical to the scalar path.
# ---------------------------------------------------------------------------

def ceil_div_batch(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Elementwise ``ceil(a / b)`` on integer arrays."""
    return -(-a // b)


def grid_dims_batch(nodes: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Vector form of :func:`grid_dims`."""
    gh = np.ceil(np.sqrt(nodes)).astype(np.int64)
    gw = np.ceil(nodes / gh).astype(np.int64)
    return gh, gw


def conv_flops_per_elem_batch(conv_t: np.ndarray, in_c: np.ndarray,
                              k: np.ndarray,
                              fan_in: np.ndarray) -> np.ndarray:
    """Vector form of the per-output-element FLOP factor of
    :func:`_conv_row_flops` (everything except the output region size)."""
    return np.select(
        [(conv_t == ConvT.CONV) | (conv_t == ConvT.POINTWISE),
         conv_t == ConvT.DWCONV,
         conv_t == ConvT.POOL,
         (conv_t == ConvT.FC) | (conv_t == ConvT.ATTN)
         | (conv_t == ConvT.FFN),
         conv_t == ConvT.ADD],
        [2.0 * in_c * k * k,
         2.0 * k * k,
         1.0 * k * k,
         2.0 * in_c,
         np.maximum(1, fan_in - 1) * 1.0],
        default=1.0)  # CONCAT: copy cost


def straggler_flops_batch(per_elem: np.ndarray, oh: np.ndarray,
                          ow: np.ndarray, oc: np.ndarray,
                          scheme: np.ndarray, nodes: np.ndarray,
                          halo: np.ndarray,
                          flop_factor: np.ndarray,
                          heads: np.ndarray = None) -> np.ndarray:
    """Vector form of ``shard_work(...).straggler_flops``.

    The 1-D schemes reduce to the ceil-shard in closed form (workload is
    monotone in shard extent, so the straggler is the first shard of the
    balanced split).  GRID2D replays the round-robin cell assignment per
    distinct node count, accumulating cells in the scalar order.  Rows with
    ``heads > 0`` (ATTN layers) split OutC at head granularity.
    """
    if np.any((halo > 0) & (scheme == Scheme.OUTC)):
        raise ValueError("NT halo is undefined for OutC partition")
    if heads is None:
        heads = np.zeros(per_elem.shape, np.int64)
    out = np.empty(per_elem.shape, np.float64)

    m = scheme == Scheme.INH
    if m.any():
        r = np.minimum(ceil_div_batch(oh[m], nodes[m]) + 2 * halo[m], oh[m])
        out[m] = per_elem[m] * r * ow[m] * oc[m] * flop_factor[m]
    m = scheme == Scheme.INW
    if m.any():
        c = np.minimum(ceil_div_batch(ow[m], nodes[m]) + 2 * halo[m], ow[m])
        out[m] = per_elem[m] * oh[m] * c * oc[m] * flop_factor[m]
    m = scheme == Scheme.OUTC
    if m.any():
        h = np.maximum(heads[m], 1)
        ch = np.where(heads[m] > 0,
                      ceil_div_batch(h, nodes[m]) * (oc[m] // h),
                      ceil_div_batch(oc[m], nodes[m]))
        out[m] = per_elem[m] * oh[m] * ow[m] * ch * flop_factor[m]
    gmask = scheme == Scheme.GRID2D
    for nval in np.unique(nodes[gmask]) if gmask.any() else ():
        m = gmask & (nodes == nval)
        gh, gw = grid_dims(int(nval))
        q_r, rem_r = oh[m] // gh, oh[m] % gh
        q_c, rem_c = ow[m] // gw, ow[m] % gw
        acc = np.zeros((int(nval), int(m.sum())), np.float64)
        for j in range(gh * gw):   # round-robin cells, scalar order
            r = q_r + (j // gw < rem_r)
            c = q_c + (j % gw < rem_c)
            rr = np.minimum(r + 2 * halo[m], oh[m])
            cc = np.minimum(c + 2 * halo[m], ow[m])
            acc[j % int(nval)] += \
                per_elem[m] * rr * cc * oc[m] * flop_factor[m]
        out[m] = acc.max(axis=0)
    return out


def weighted_split_batch(total: np.ndarray,
                         weights: np.ndarray) -> np.ndarray:
    """Vector form of :func:`weighted_split_sizes`: one shared weight vector,
    a batch of totals.  Returns an ``(n_rows, n_devices)`` int64 matrix,
    row-for-row identical to the scalar largest-remainder split."""
    total = np.asarray(total, np.int64)
    w = np.asarray(weights, np.float64)
    if np.any(w < 0.0):
        raise ValueError(f"negative capability weight in {w}")
    s = float(w.sum())
    if s <= 0.0:
        raise ValueError("capability weights must sum to a positive value")
    ideal = total[:, None] * w[None, :] / s
    base = np.floor(ideal).astype(np.int64)
    rem = total - base.sum(axis=1)
    order = np.argsort(base - ideal, axis=1, kind="stable")
    rank = np.empty_like(order)
    np.put_along_axis(rank, order,
                      np.broadcast_to(np.arange(len(w)), order.shape), axis=1)
    return base + (rank < rem[:, None])


def hetero_flops_batch(per_elem: np.ndarray, oh: np.ndarray, ow: np.ndarray,
                       oc: np.ndarray, scheme: np.ndarray, halo: np.ndarray,
                       flop_factor: np.ndarray,
                       weights: np.ndarray,
                       heads: np.ndarray = None) -> np.ndarray:
    """Vector form of ``hetero_shard_work(...).flops_per_node`` over stacked
    feature columns: returns the full ``(n_rows, n_devices)`` per-device
    FLOP matrix (the cost model divides by per-device speeds and takes the
    straggler max).  Expression order mirrors the scalar path so uniform
    weights stay bit-identical to :func:`straggler_flops_batch`.  Rows with
    ``heads > 0`` (ATTN layers) split OutC at head granularity."""
    if np.any((halo > 0) & (scheme == Scheme.OUTC)):
        raise ValueError("NT halo is undefined for OutC partition")
    if heads is None:
        heads = np.zeros(per_elem.shape, np.int64)
    ndev = len(weights)
    out = np.empty((len(per_elem), ndev), np.float64)

    def _oned(m: np.ndarray, extent: np.ndarray, clip_halo: bool) -> \
            np.ndarray:
        e = weighted_split_batch(extent[m], weights)
        if clip_halo:
            e = np.minimum(e + 2 * halo[m][:, None], extent[m][:, None])
        return e

    m = scheme == Scheme.INH
    if m.any():
        r = _oned(m, oh, True)
        out[m] = per_elem[m][:, None] * r * ow[m][:, None] \
            * oc[m][:, None] * flop_factor[m][:, None]
    m = scheme == Scheme.INW
    if m.any():
        c = _oned(m, ow, True)
        out[m] = per_elem[m][:, None] * oh[m][:, None] * c \
            * oc[m][:, None] * flop_factor[m][:, None]
    m = scheme == Scheme.OUTC
    if m.any():
        h = np.maximum(heads[m], 1)
        ch_head = weighted_split_batch(h, weights) * (oc[m] // h)[:, None]
        ch = np.where((heads[m] > 0)[:, None], ch_head, _oned(m, oc, False))
        out[m] = per_elem[m][:, None] * oh[m][:, None] * ow[m][:, None] \
            * ch * flop_factor[m][:, None]
    m = scheme == Scheme.GRID2D
    if m.any():
        # balanced round-robin cell grid (see hetero_shard_work), replayed
        # in the scalar accumulation order per node
        gh, gw = grid_dims(ndev)
        q_r, rem_r = oh[m] // gh, oh[m] % gh
        q_c, rem_c = ow[m] // gw, ow[m] % gw
        acc = np.zeros((ndev, int(m.sum())), np.float64)
        for j in range(gh * gw):
            r = q_r + (j // gw < rem_r)
            c = q_c + (j % gw < rem_c)
            rr = np.minimum(r + 2 * halo[m], oh[m])
            cc = np.minimum(c + 2 * halo[m], ow[m])
            acc[j % ndev] += per_elem[m] * rr * cc * oc[m] * flop_factor[m]
        out[m] = acc.T
    return out


def boundary_bytes_same_scheme_batch(scheme: np.ndarray, oh: np.ndarray,
                                     ow: np.ndarray, oc: np.ndarray,
                                     nodes: np.ndarray,
                                     next_k: np.ndarray) -> np.ndarray:
    """Vector form of :func:`boundary_bytes_same_scheme`.  Non-spatial rows
    (which the scalar form rejects) yield 0 and must be masked by the
    caller."""
    halo = np.maximum(next_k - 1, 0)
    gh, gw = grid_dims_batch(nodes)
    rows = np.ceil(oh / gh)
    cols = np.ceil(ow / gw)
    vals = np.select(
        [scheme == Scheme.INH, scheme == Scheme.INW,
         scheme == Scheme.GRID2D],
        [2.0 * halo * ow * oc * DTYPE_BYTES,
         2.0 * halo * oh * oc * DTYPE_BYTES,
         2.0 * halo * (cols + rows + halo) * oc * DTYPE_BYTES],
        default=0.0)
    return np.where((halo == 0) | (nodes <= 1), 0.0, vals)


def relayout_bytes_batch(oh: np.ndarray, ow: np.ndarray, oc: np.ndarray,
                         src: np.ndarray, dst: np.ndarray,
                         nodes: np.ndarray) -> np.ndarray:
    """Vector form of :func:`relayout_bytes`."""
    total = (oh * ow * oc) * DTYPE_BYTES
    frac_missing = (nodes - 1) / nodes
    shuffle = (total / nodes) * frac_missing * 2.0
    return np.select(
        [dst == Scheme.OUTC, src == Scheme.OUTC, src == dst],
        [total * frac_missing, shuffle, 0.0],
        default=shuffle)


def relayout_bytes(layer: LayerSpec, src: Scheme, dst: Scheme,
                   nodes: int) -> float:
    """Bytes the busiest node must receive to transform the output of
    ``layer`` from layout ``src`` into the input layout ``dst`` requires.

    OutC destination needs the *full* feature map on every node (the costly
    gather the paper calls out); OutC source means every node holds a channel
    slice of every position, so any spatial destination is an all-to-all.
    """
    total = layer.out_elems() * DTYPE_BYTES
    frac_missing = (nodes - 1) / nodes
    if dst == Scheme.OUTC:
        # every node must hold the full input -> gather everything missing
        return total * frac_missing
    if src == Scheme.OUTC:
        # channel slices -> spatial slices: each node keeps 1/nodes of what it
        # has and scatters the rest; receives (nodes-1)/nodes of its spatial
        # shard from peers.
        return (total / nodes) * frac_missing * 2.0
    if src == dst:
        return 0.0  # same spatial layout; only halo (handled separately)
    # spatial -> different spatial (e.g. InH -> InW): full re-shard
    return (total / nodes) * frac_missing * 2.0
