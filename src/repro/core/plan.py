"""Partition plans and their cost semantics.

A plan assigns every layer ``L_i`` a pair ``P_i = (p_i, t_i)`` (§3.3).  The
cost semantics shared by DPP, the exhaustive oracle and all baselines:

* The plan decomposes into **segments** — maximal runs ``[a..b]`` with
  ``t_a .. t_{b-1} = NT`` and ``t_b = T`` (the last layer is always T,
  Algorithm 1 lines 11-12).
* Within a multi-layer segment every layer must use the *same spatial* scheme
  (halo-fused redundant compute is only meaningful when consecutive layers
  share a spatial split; OutC needs the full next-layer input, so OutC can
  never be in NT mode).
* Layer ``m`` of segment ``[a..b]`` computes an output enlarged by the
  receptive-field halo ``h_m`` (``graph.halo_growth``) — the redundant
  computation of §2.3.
* Each segment end pays the s-cost to re-layout its output into the next
  segment's scheme; the final layer pays a gather-to-root sync.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from .cost import Testbed
from .estimator import CostEstimator
from .graph import LayerSpec, ModelGraph, halo_growth
from .partition import Mode, Scheme, min_shard_extent


@dataclasses.dataclass(frozen=True)
class Plan:
    """``steps[i] = (scheme, mode)`` for layer i."""

    steps: Tuple[Tuple[Scheme, Mode], ...]

    def __post_init__(self) -> None:
        if self.steps and self.steps[-1][1] != Mode.T:
            raise ValueError("last layer must be in T mode")

    def __len__(self) -> int:
        return len(self.steps)

    def segments(self) -> List[Tuple[int, int]]:
        """Inclusive (start, end) of each T-terminated segment."""
        segs, a = [], 0
        for i, (_, t) in enumerate(self.steps):
            if t == Mode.T:
                segs.append((a, i))
                a = i + 1
        return segs

    def validate(self) -> None:
        for a, b in self.segments():
            if b > a:
                schemes = {self.steps[m][0] for m in range(a, b + 1)}
                if len(schemes) != 1:
                    raise ValueError(
                        f"segment [{a},{b}] mixes schemes {schemes}")
                if not self.steps[a][0].spatial:
                    raise ValueError(
                        f"segment [{a},{b}] uses non-spatial scheme in NT mode")


def plan_cost(graph: ModelGraph, plan: Plan, est: CostEstimator,
              tb: Testbed) -> float:
    """Total estimated inference time of ``plan`` (seconds)."""
    if len(plan) != len(graph):
        raise ValueError("plan/graph length mismatch")
    plan.validate()
    layers = graph.layers
    total = 0.0
    segs = plan.segments()
    for a, b in segs:
        scheme = plan.steps[a][0]
        halos = halo_growth(layers[a:b + 1], b - a)
        for off, m in enumerate(range(a, b + 1)):
            total += est.i_cost(layers[m], scheme, tb,
                                extra_halo=halos[off] if b > a else 0)
        nxt = layers[b + 1] if b + 1 < len(layers) else None
        dst = plan.steps[b + 1][0] if b + 1 < len(layers) else None
        total += est.s_cost(layers[b], nxt, scheme, dst, tb)
    return total


def segment_halos(layers: Sequence[LayerSpec], a: int, b: int) -> List[int]:
    """Halo (extra output rows per side) for each layer of segment [a..b]."""
    return halo_growth(layers[a:b + 1], b - a)


def segment_feasible(layers: Sequence[LayerSpec], a: int, b: int,
                     scheme: Scheme, nodes: int) -> bool:
    """A multi-layer NT segment is feasible while its cumulative halo has not
    degenerated into full replication.  Shared by DPP (as a prune — the halo
    is monotone in segment length, so breaking early is exact) and by the
    exhaustive oracle (as a plan filter), keeping their search spaces equal.
    """
    if b == a:
        return True
    if not scheme.spatial:
        return False
    halos = halo_growth(layers[a:b + 1], b - a)
    return 2 * halos[0] < min_shard_extent(layers[a], scheme, nodes)


def plan_feasible(graph: ModelGraph, plan: Plan, nodes: int) -> bool:
    return all(segment_feasible(graph.layers, a, b, plan.steps[a][0], nodes)
               for a, b in plan.segments())


def fixed_plan(graph: ModelGraph, scheme: Scheme) -> Plan:
    return Plan(tuple((scheme, Mode.T) for _ in graph.layers))
