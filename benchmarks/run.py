# One function per paper table. Print ``name,us_per_call,derived`` CSV.
# ``--json [PATH]`` additionally writes the search-time records to
# BENCH_search.json (default) for the CI perf-trajectory artifact.
# ``--trace-dir PATH`` captures Perfetto traces + metrics snapshots from
# the mesh and churn benches into PATH (see repro.obs).
from __future__ import annotations

import sys


def main(argv: list[str] | None = None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    from .common import json_arg, trace_dir_arg
    json_path = json_arg(argv)
    trace_dir = trace_dir_arg(argv)

    from . import (churn_bench, decode_bench, engine_comm,
                   estimator_quality, fig2_microbench,
                   fig7_fig9_comparison, fig8_score, kernel_bench,
                   mesh_bench, roofline_table, search_time, sweep, tpu_ce)
    print("name,us_per_call,derived")
    fig2_microbench.run()
    fig7_fig9_comparison.run(4, "fig7")
    fig7_fig9_comparison.run(3, "fig9")
    fig8_score.run()
    search_time.run(json_path=json_path)
    # heterogeneous-cluster scale sweep, reduced grid (full grid + JSON via
    # benchmarks.sweep --json)
    sweep.run(smoke=True)
    engine_comm.run()
    # Pallas-vs-XLA shard kernel timings + conformance flags (JSON via
    # benchmarks.kernel_bench --json)
    kernel_bench.run()
    # mesh executor vs single-process engine, reduced model set (full set
    # + JSON via benchmarks.mesh_bench --json; respawns with fake devices)
    mesh_bench.run(smoke=True, trace_dir=trace_dir)
    # elastic-cluster churn replay: gated scenarios only (full scenario
    # set + JSON via benchmarks.churn_bench --full --json)
    churn_bench.run(smoke=True, trace_dir=trace_dir)
    # autoregressive decode: sharded-vs-oracle flags + tok/s, smoke grid
    # (full spec x nodes grid + JSON via benchmarks.decode_bench --json)
    decode_bench.run(smoke=True)
    # data-driven CE: small trace budget by default (full 330K via
    # benchmarks.estimator_quality --full)
    estimator_quality.run(n_samples=8_000, trees=40)
    roofline_table.run()
    tpu_ce.run()


if __name__ == "__main__":
    main()
