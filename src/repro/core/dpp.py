"""Dynamic Partition Planner — Algorithm 1 (§3.3), extended to DAGs.

Reverse-order DP over T-states.  ``S[i][p]`` is the optimal remaining time
from layer ``i`` to the end, given layer ``i``'s input is exactly sharded in
layout ``p``.  NT runs appear only *inside* segments ``[i..b]`` that start and
end at T boundaries — exactly the paper's Key designs 1-3: an NT-prefixed
subsequence has indeterminate workload (footnote 3), so such states are never
evaluated on their own.

Pruning (the paper's "piecing together" list):
  1. reverse search never expands NT-start states (they exist only inside
     segment enumeration);
  2. suffix costs ``S[b+1][p']`` are reused across all segments ending at b;
  3. dynamic threshold — segment cost is monotone in segment length, so the
     backtrack stops as soon as the partial segment cost alone exceeds the
     incumbent (and when the halo swallows the whole shard, at which point
     redundant compute has degenerated into full replication).

Branched graphs (fan-in/fan-out >= 2) run the same reverse DP **per branch**
of ``ModelGraph.linearize()`` and compose at junctions: branch tails and
junction layers are forced T-mode sync points, fork deliveries are summed,
and each merge pays the max over its incoming branch re-layouts (see
``plan.dag_plan_cost`` — the DP and the cost function share one semantics,
which is what keeps the Theorem-1 oracle property on DAGs).  The junction
skeleton must be a "ladder" — parallel branch bundles between consecutive
fork/merge points, which covers residual blocks and Inception-style modules;
arbitrary multi-source or nested-fork DAGs raise ``ValueError``.

Two drivers share that search structure:

* :func:`plan_search` — the production path.  Every i-/s-cost the DP can
  touch is precomputed through ``core.cost_tables`` in one batched
  ``i_cost_batch`` + one ``s_cost_batch`` estimator call, the chain DP
  becomes numpy reductions over the scheme axis, and ``SearchStats`` is
  derived from the table masks.
* :func:`plan_search_reference` — the original scalar-call implementation,
  kept verbatim as the parity oracle.  Both estimators guarantee their
  batched entry points bit-match the scalar ones, and the batched DP
  replicates the scalar tie-breaking (first minimum wins in ``b`` then
  ``q`` order), so both drivers return bit-identical plans and costs.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cost import Testbed
from .cost_tables import CostTableBuilder, plan_chain_tables
from .estimator import CostEstimator
from .graph import ModelGraph, halo_growth
from .partition import ALL_SCHEMES, Mode, Scheme, min_shard_extent
from .plan import Plan

_INF = float("inf")


@dataclasses.dataclass
class SearchStats:
    i_calls: int = 0
    s_calls: int = 0
    states: int = 0
    pruned_threshold: int = 0
    pruned_halo: int = 0


@dataclasses.dataclass(frozen=True)
class SearchResult:
    plan: Plan
    cost: float
    stats: SearchStats


def plan_search(graph: ModelGraph, est: CostEstimator, tb: Testbed,
                schemes: Sequence[Scheme] = ALL_SCHEMES,
                max_segment: int = 32,
                allow_fusion: bool = True) -> SearchResult:
    """Run DPP from precomputed batched cost tables.  ``allow_fusion=False``
    restricts to all-T plans (the layerwise baseline); ``schemes``
    restricted to one scheme with fusion on gives the fused-layer baseline.
    Dispatches to the per-branch DAG composition when the graph is not a
    chain.  Returns the same plan and cost as
    :func:`plan_search_reference`, bit for bit.

    The batched tables assume the estimator is determined by the feature
    expression (the ``i_cost_batch`` contract).  Estimators that only
    implement the scalar protocol — e.g. oracles keyed on layer *names* —
    run the scalar reference unchanged."""
    if not hasattr(est, "i_cost_batch"):
        return plan_search_reference(graph, est, tb, schemes, max_segment,
                                     allow_fusion)
    if not graph.is_chain:
        return _dag_plan_search_batched(graph, est, tb, tuple(schemes),
                                        max_segment, allow_fusion)
    return _chain_plan_search_batched(graph, est, tb, tuple(schemes),
                                      max_segment, allow_fusion)


# ---------------------------------------------------------------------------
# Batched chain DP: numpy reductions over the (scheme x segment-length) axes.
# ---------------------------------------------------------------------------

def _chain_plan_search_batched(graph: ModelGraph, est: CostEstimator,
                               tb: Testbed, schemes: Tuple[Scheme, ...],
                               max_segment: int,
                               allow_fusion: bool) -> SearchResult:
    layers = graph.layers
    n = len(layers)
    k = len(schemes)

    builder = CostTableBuilder(est, tb)
    fin = plan_chain_tables(layers, builder, schemes, max_segment,
                            allow_fusion, tb.nodes, with_final=True)
    tbl = fin(*builder.evaluate())
    seg = tbl.seg                        # (n, k, cap), +inf = inadmissible
    cap = seg.shape[2]

    S = np.full((n + 1, k), _INF)
    choice_b = np.full((n, k), -1, np.int64)
    choice_q = np.full((n, k), -1, np.int64)
    ks = np.arange(k)
    for i in range(n - 1, -1, -1):
        m = min(cap, n - i)
        # cand[p, L, q] = (seg + boundary s-cost) + suffix — the same float
        # association as the scalar reference, so costs stay bit-identical
        cand = np.full((k, m, k), _INF)
        Lf = n - 1 - i                      # L index of a graph-final segment
        if Lf < m:
            cand[:, Lf, 0] = seg[i, :, Lf] + tbl.s_final
        mn = min(m, Lf)                     # segments with a next layer
        if mn > 0:
            sb = tbl.sbound[i:i + mn].transpose(1, 0, 2)       # (p, L, q)
            cand[:, :mn, :] = (seg[i, :, :mn, None] + sb) \
                + S[i + 1:i + 1 + mn][None, :, :]
        flat = cand.reshape(k, m * k)
        fi = np.argmin(flat, axis=1)        # first min: b-major, q-minor —
        S[i] = flat[ks, fi]                 # the scalar scan order
        Lb = fi // k
        choice_b[i] = i + Lb
        choice_q[i] = np.where(Lb == Lf, -1, fi % k)

    pi = int(np.argmin(S[0]))
    total = float(S[0][pi])

    steps: List[Tuple[Scheme, Mode]] = []
    i = 0
    while i < n:
        b, qi = int(choice_b[i][pi]), int(choice_q[i][pi])
        p = schemes[pi]
        for m2 in range(i, b + 1):
            steps.append((p, Mode.NT if m2 < b else Mode.T))
        i = b + 1
        if qi >= 0:
            pi = qi

    stats = SearchStats(
        i_calls=builder.i_entries, s_calls=builder.s_entries,
        states=n * k, pruned_halo=tbl.halo_cuts,
        pruned_threshold=_threshold_prunes(seg, S[:n]))
    return SearchResult(plan=Plan(tuple(steps)), cost=total, stats=stats)


def _threshold_prunes(seg: np.ndarray, S: np.ndarray) -> int:
    """Dynamic-threshold prune counter, derived from the table masks: a
    state (i, p) counts as pruned when some admissible segment's i-cost
    alone already reaches the state's optimal remaining time — exactly the
    candidates the scalar backtrack refuses to extend."""
    with np.errstate(invalid="ignore"):
        hit = (seg != _INF) & (seg >= S[:, :, None]) & \
            np.isfinite(S[:, :, None])
    return int(hit.any(axis=2).sum())


# ---------------------------------------------------------------------------
# Shared per-branch chain DP with pinned boundary layouts (used by both the
# batched and reference DAG drivers — only the cost lookups differ).
# ---------------------------------------------------------------------------

def _pinned_chain_dp(n: int, schemes: Tuple[Scheme, ...],
                     seg_costs: Callable[[int, int], List[Tuple[int, float]]],
                     bound_cost: Callable[[int, int, int], float],
                     stats: SearchStats) -> Dict[Tuple[int, int],
                                                 Tuple[float, tuple]]:
    """Reverse DP over one branch with pinned boundary layouts.

    Returns ``{(head_idx, tail_idx): (cost, steps)}`` — the minimal
    *internal* cost of the branch (i-costs with halos + s-costs at internal
    T boundaries; no entry delivery, no exit delivery/gather) with the first
    segment using ``schemes[head_idx]`` and the last ``schemes[tail_idx]``.
    ``seg_costs(i, pi)`` yields the admissible ``(b, segcost)`` options in
    ascending ``b`` order (already reflecting any head pinning).
    """
    k = len(schemes)
    tables: Dict[Tuple[int, int], Tuple[float, tuple]] = {}
    for ti in range(k):
        S = [[_INF] * k for _ in range(n)]
        choice = [[(-1, -1)] * k for _ in range(n)]
        for i in range(n - 1, -1, -1):
            for pi in range(k):
                best, best_choice = _INF, (-1, -1)
                stats.states += 1
                for b, segcost in seg_costs(i, pi):
                    if segcost >= best:
                        stats.pruned_threshold += 1
                        break
                    if b == n - 1:
                        if pi == ti and segcost < best:
                            best, best_choice = segcost, (b, -1)
                    else:
                        for qi in range(k):
                            if S[b + 1][qi] == _INF:
                                continue
                            c = (segcost + bound_cost(b, pi, qi)
                                 + S[b + 1][qi])
                            if c < best:
                                best, best_choice = c, (b, qi)
                S[i][pi] = best
                choice[i][pi] = best_choice
        for pi in range(k):
            if S[0][pi] == _INF:
                continue
            steps: List[Tuple[Scheme, Mode]] = []
            i, cp = 0, pi
            while i < n:
                b, qi = choice[i][cp]
                p = schemes[cp]
                for m in range(i, b + 1):
                    steps.append((p, Mode.NT if m < b else Mode.T))
                i = b + 1
                if qi >= 0:
                    cp = qi
            tables[(pi, ti)] = (S[0][pi], tuple(steps))
    return tables


def _scalar_chain_tables(ls, icost, scost, schemes, max_segment,
                         allow_fusion, head_solo, nodes, stats):
    """Reference (scalar-call) segment/boundary providers + pinned DP."""
    n = len(ls)
    k = len(schemes)

    # Segment and boundary costs are identical across the k tail pins, so
    # compute each once (lazily) and share them between the per-tail DPs.
    seg_cache: Dict[Tuple[int, int], List[Tuple[int, float]]] = {}
    bound_cache: Dict[Tuple[int, int, int], float] = {}

    def seg_costs(i: int, pi: int) -> List[Tuple[int, float]]:
        hit = seg_cache.get((i, pi))
        if hit is not None:
            return hit
        p = schemes[pi]
        out: List[Tuple[int, float]] = []
        seg_hi = min(i + max_segment, n) if allow_fusion else i + 1
        if head_solo and i == 0:
            seg_hi = i + 1
        for b in range(i, seg_hi):
            if b > i and not p.spatial:
                break
            halos = halo_growth(ls[i:b + 1], b - i)
            if b > i and 2 * halos[0] >= min_shard_extent(ls[i], p, nodes):
                stats.pruned_halo += 1
                break
            segcost = 0.0
            for off, m in enumerate(range(i, b + 1)):
                segcost += icost(ls[m], p, halos[off] if b > i else 0)
            out.append((b, segcost))
        seg_cache[(i, pi)] = out
        return out

    def bound_cost(b: int, pi: int, qi: int) -> float:
        key = (b, pi, qi)
        hit = bound_cache.get(key)
        if hit is None:
            hit = scost(ls[b], ls[b + 1], schemes[pi], schemes[qi])
            bound_cache[key] = hit
        return hit

    return _pinned_chain_dp(n, schemes, seg_costs, bound_cost, stats)


# ---------------------------------------------------------------------------
# DAG composition: per-branch chain tables + ladder DP over junctions.
# ---------------------------------------------------------------------------

def _ladder(graph: ModelGraph):
    """Condense the DAG's branches into a spine with parallel bundles.

    Returns ``(branches, spine, bundles)`` where ``spine`` is a list of
    branch indices and ``bundles[t] = (interior_branch_ids, n_direct)``
    describes the parallel branches (plus identity skip edges) between
    ``spine[t]``'s tail (the fork) and ``spine[t+1]``'s head (the merge).
    """
    branches = graph.linearize()
    n_br = len(branches)
    bidx: Dict[int, int] = {}
    for t, br in enumerate(branches):
        for i in br.ids:
            bidx[i] = t
    preds: List[set] = [set() for _ in range(n_br)]
    succs: List[set] = [set() for _ in range(n_br)]
    for i, prods in enumerate(graph.producer_ids):
        for j in prods:
            if j >= 0 and bidx[j] != bidx[i]:
                preds[bidx[i]].add(bidx[j])
                succs[bidx[j]].add(bidx[i])
    sources = [t for t in range(n_br) if not preds[t]]
    if len(sources) != 1:
        raise ValueError(
            f"{graph.name}: plan_search needs a single-source DAG "
            f"(got {len(sources)} source branches)")
    spine = [sources[0]]
    bundles: List[Tuple[List[int], int]] = []
    cur = sources[0]
    used = {cur}
    while succs[cur]:
        interior: List[int] = []
        merges: set = set()
        for b in sorted(succs[cur]):
            if graph.fan_in(branches[b].head) >= 2:
                merges.add(b)
            else:
                interior.append(b)
        for b in interior:
            if preds[b] != {cur} or len(succs[b]) != 1:
                raise ValueError(
                    f"{graph.name}: nested fork at branch {b} — only "
                    f"fork -> parallel branches -> merge ladders are "
                    f"supported by plan_search")
            merges.update(succs[b])
        if len(merges) != 1:
            raise ValueError(
                f"{graph.name}: branches from {branches[cur].tail} do not "
                f"reconverge at a single merge — not a ladder DAG")
        nxt = merges.pop()
        if not preds[nxt] <= set(interior) | {cur}:
            raise ValueError(
                f"{graph.name}: merge at layer "
                f"{graph.layers[branches[nxt].head].name} has inputs from "
                f"outside its bundle — not a ladder DAG")
        n_direct = sum(1 for j in graph.producer_ids[branches[nxt].head]
                       if j == branches[cur].tail)
        bundles.append((interior, n_direct))
        spine.append(nxt)
        used.add(nxt)
        used.update(interior)
        cur = nxt
    if len(used) != n_br:
        raise ValueError(f"{graph.name}: {n_br - len(used)} branches are "
                         f"unreachable along the ladder — unsupported DAG")
    return branches, spine, bundles


def _dag_compose(graph: ModelGraph, schemes: Tuple[Scheme, ...],
                 btable: Callable[[int, bool], Dict],
                 jscost: Callable[[int, Optional[int], int, Optional[int]],
                                  float],
                 stats: SearchStats) -> SearchResult:
    """Ladder DP over junctions, shared by the batched and reference
    drivers.  ``btable(branch, head_solo)`` returns the pinned chain tables
    of one branch; ``jscost(prod_id, cons_id, pi, qi)`` the junction
    delivery s-cost (``cons_id=None``/``qi=None`` is the final gather)."""
    branches, spine, bundles = _ladder(graph)
    layers = graph.layers
    k = len(schemes)
    K = len(spine)

    spine_tab = [btable(s, idx > 0) for idx, s in enumerate(spine)]
    interior_tab = {b: btable(b, False)
                    for ints, _ in bundles for b in ints}

    # min over head schemes of (fork delivery + branch internal cost), per
    # (fork tail scheme, branch tail scheme)
    ib_memo: Dict[Tuple[int, int, int], Tuple[float, int]] = {}

    def ib_entry(b: int, qf_i: int, pt_i: int) -> Tuple[float, int]:
        key = (b, qf_i, pt_i)
        hit = ib_memo.get(key)
        if hit is not None:
            return hit
        fork_id = graph.producer_ids[branches[b].head][0]
        head_id = branches[b].head
        best: Tuple[float, int] = (_INF, -1)
        for ph_i in range(k):
            e = interior_tab[b].get((ph_i, pt_i))
            if e is None:
                continue
            c = jscost(fork_id, head_id, qf_i, ph_i) + e[0]
            if c < best[0]:
                best = (c, ph_i)
        ib_memo[key] = best
        return best

    bundle_memo: Dict[Tuple[int, int, int], Tuple[float, Optional[list]]] = {}

    def bundle_solve(t: int, pt_i: int, qm_i: int):
        """Min cost of delivering the bundle between spine t and t+1, given
        the fork tail scheme and merge head scheme.  Per-branch internal and
        fork-delivery costs sum; merge deliveries combine with max.  Exact:
        enumerate which delivery attains the max, pin it, and let every
        other branch independently take its cheapest option whose delivery
        fits under it.

        The candidate scan is vectorized over the (branch x tail-scheme)
        option tables: one (candidate, branch, scheme) feasibility tensor,
        first-min reductions matching the scalar tie-breaking, and a
        branch-ordered accumulation that keeps totals bit-identical to the
        historical per-candidate loop (matters for wide Inception-style
        bundles, where candidates x branches x schemes dominates)."""
        key = (t, pt_i, qm_i)
        hit = bundle_memo.get(key)
        if hit is not None:
            return hit
        ints, n_direct = bundles[t]
        fork_id = branches[spine[t]].tail
        merge_id = branches[spine[t + 1]].head
        d0 = jscost(fork_id, merge_id, pt_i, qm_i) if n_direct else None
        if not ints:
            res = (d0 if d0 is not None else 0.0, [])
            bundle_memo[key] = res
            return res
        nb = len(ints)
        # option tables, indexed by tail-scheme pti (inf = infeasible)
        C = np.full((nb, k), _INF)    # fork delivery + branch internal cost
        D = np.full((nb, k), _INF)    # merge delivery cost
        PH = np.full((nb, k), -1, np.int64)
        for bi, b in enumerate(ints):
            tail_id = branches[b].tail
            for pti in range(k):
                c, ph_i = ib_entry(b, pt_i, pti)
                if c == _INF:
                    continue
                C[bi, pti] = c
                D[bi, pti] = jscost(tail_id, merge_id, pti, qm_i)
                PH[bi, pti] = ph_i
            if not np.isfinite(C[bi]).any():
                bundle_memo[key] = (_INF, None)
                return (_INF, None)
        # candidates for "which delivery attains the merge max", in the
        # scalar scan order: the direct skip edge first, then options
        # branch-major / scheme-minor
        fbi, foi = np.nonzero(np.isfinite(C))
        m_vec = D[fbi, foi]
        fb = fbi
        fo = foi
        if d0 is not None:
            m_vec = np.concatenate(([d0], m_vec))
            fb = np.concatenate(([-1], fb))
            fo = np.concatenate(([-1], fo))
        feas = D[None, :, :] <= m_vec[:, None, None]
        cm = np.where(feas, C[None, :, :], _INF)
        best_oi = np.argmin(cm, axis=2)               # first min, pti order
        bc = np.take_along_axis(cm, best_oi[:, :, None], 2)[:, :, 0]
        bc_eff = bc.copy()
        rows = np.arange(len(m_vec))
        pin = fb >= 0
        bc_eff[rows[pin], fb[pin]] = C[fb[pin], fo[pin]]
        valid = np.isfinite(bc).all(axis=1)
        if d0 is not None:
            valid &= d0 <= m_vec
        totals = m_vec.copy()
        for bi in range(nb):          # branch order = scalar accumulation
            totals = totals + bc_eff[:, bi]
        totals = np.where(valid, totals, _INF)
        win = int(np.argmin(totals))
        best_total = float(totals[win])
        if best_total == _INF:
            bundle_memo[key] = (_INF, None)
            return (_INF, None)
        best_assign = []
        for bi in range(nb):
            pti = int(fo[win]) if bi == fb[win] else int(best_oi[win, bi])
            best_assign.append((ints[bi], int(PH[bi, pti]), pti))
        bundle_memo[key] = (best_total, best_assign)
        return best_total, best_assign

    # ---- spine DP (reverse) -----------------------------------------------
    # V[t][ph] = (cost from spine t's head onward, tail scheme, next head)
    V: List[Dict[int, Tuple[float, int, int]]] = [dict() for _ in range(K)]
    tail_id = branches[spine[-1]].tail
    for ph_i in range(k):
        best = (_INF, -1, -1)
        for pt_i in range(k):
            e = spine_tab[K - 1].get((ph_i, pt_i))
            if e is None:
                continue
            c = e[0] + jscost(tail_id, None, pt_i, None)
            if c < best[0]:
                best = (c, pt_i, -1)
        if best[0] < _INF:
            V[K - 1][ph_i] = best
    for t in range(K - 2, -1, -1):
        for ph_i in range(k):
            best = (_INF, -1, -1)
            for pt_i in range(k):
                e = spine_tab[t].get((ph_i, pt_i))
                if e is None:
                    continue
                for ph2, (suffix, _, _) in V[t + 1].items():
                    bc, _assign = bundle_solve(t, pt_i, ph2)
                    c = e[0] + bc + suffix
                    if c < best[0]:
                        best = (c, pt_i, ph2)
            if best[0] < _INF:
                V[t][ph_i] = best
    if not V[0]:
        raise RuntimeError(f"{graph.name}: no feasible plan found")
    ph = min(V[0], key=lambda p: V[0][p][0])
    total = V[0][ph][0]

    # ---- reconstruction ---------------------------------------------------
    steps: List[Optional[Tuple[Scheme, Mode]]] = [None] * len(layers)
    for t in range(K):
        _, pt_i, ph_next = V[t][ph]
        for idx, st in zip(branches[spine[t]].ids,
                           spine_tab[t][(ph, pt_i)][1]):
            steps[idx] = st
        if t < K - 1:
            _, assign = bundle_solve(t, pt_i, ph_next)
            for b, ph_b, pt_b in assign:
                for idx, st in zip(branches[b].ids,
                                   interior_tab[b][(ph_b, pt_b)][1]):
                    steps[idx] = st
            ph = ph_next
    return SearchResult(plan=Plan(tuple(steps)), cost=total, stats=stats)


def _dag_plan_search_batched(graph: ModelGraph, est: CostEstimator,
                             tb: Testbed, schemes: Tuple[Scheme, ...],
                             max_segment: int,
                             allow_fusion: bool) -> SearchResult:
    """Batched DAG driver: register every branch segment/boundary and every
    junction delivery with one table builder, evaluate in a single pair of
    batched estimator calls, then run the shared ladder composition from
    the tables."""
    stats = SearchStats()
    layers = graph.layers
    branches = graph.linearize()

    builder = CostTableBuilder(est, tb)
    # geometrically identical branches (resnet101 repeats one bottleneck
    # body 23x) share one table registration and one pinned DP
    bkeys = [tuple(builder.layer_key(layers[i]) for i in br.ids)
             for br in branches]
    uniq: Dict[tuple, int] = {}
    finalizers = []
    for t, key in enumerate(bkeys):
        if key not in uniq:
            uniq[key] = len(finalizers)
            ls = [layers[i] for i in branches[t].ids]
            finalizers.append(plan_chain_tables(
                ls, builder, schemes, max_segment, allow_fusion, tb.nodes,
                with_final=False))

    # junction deliveries: every cross-branch (producer tail, consumer)
    # edge plus the final gather, all (src, dst) scheme pairs
    jidx: Dict[Tuple[int, Optional[int], int, Optional[int]], int] = {}
    for br in branches:
        tail = br.ids[-1]
        consumers = graph.consumer_ids[tail]
        if not consumers:
            for pi, p in enumerate(schemes):
                jidx[(tail, None, pi, None)] = builder.s_index(
                    layers[tail], None, p, None)
        for c in consumers:
            for pi, p in enumerate(schemes):
                for qi, q in enumerate(schemes):
                    jidx[(tail, c, pi, qi)] = builder.s_index(
                        layers[tail], layers[c], p, q)

    ivals, svals = builder.evaluate()
    utables = [fin(ivals, svals) for fin in finalizers]
    stats.i_calls = builder.i_entries
    stats.s_calls = builder.s_entries
    stats.pruned_halo = sum(utables[u].halo_cuts for u in uniq.values())

    dp_memo: Dict[Tuple[int, bool], Dict] = {}

    def btable(t: int, head_solo: bool):
        u = uniq[bkeys[t]]
        hit = dp_memo.get((u, head_solo))
        if hit is not None:
            return hit
        tbl = utables[u]

        def seg_costs(i: int, pi: int):
            return tbl.seg_options(i, pi, head_solo)

        out = _pinned_chain_dp(len(branches[t]), schemes, seg_costs,
                               tbl.bound, stats)
        dp_memo[(u, head_solo)] = out
        return out

    def jscost(prod: int, cons: Optional[int], pi: int,
               qi: Optional[int]) -> float:
        return float(svals[jidx[(prod, cons, pi, qi)]])

    return _dag_compose(graph, schemes, btable, jscost, stats)


# ---------------------------------------------------------------------------
# Reference (scalar-call) driver — kept as the parity/benchmark oracle.
# ---------------------------------------------------------------------------

def plan_search_reference(graph: ModelGraph, est: CostEstimator, tb: Testbed,
                          schemes: Sequence[Scheme] = ALL_SCHEMES,
                          max_segment: int = 32,
                          allow_fusion: bool = True) -> SearchResult:
    """Scalar-call DPP: one ``est.i_cost``/``est.s_cost`` invocation per
    sample.  Semantically identical to :func:`plan_search`; retained as the
    exactness oracle and the benchmark baseline."""
    if not graph.is_chain:
        return _dag_plan_search_reference(graph, est, tb, tuple(schemes),
                                          max_segment, allow_fusion)
    layers = graph.layers
    n = len(layers)
    k = len(schemes)
    stats = SearchStats()

    S: List[List[float]] = [[_INF] * k for _ in range(n + 1)]
    # choice[i][pi] = (segment_end_b, next_scheme_index or -1)
    choice: List[List[Tuple[int, int]]] = [[(-1, -1)] * k for _ in range(n + 1)]

    for i in range(n - 1, -1, -1):
        for pi, p in enumerate(schemes):
            best, best_choice = _INF, (-1, -1)
            stats.states += 1
            seg_hi = min(i + max_segment, n) if allow_fusion else i + 1
            for b in range(i, seg_hi):
                if b > i and not p.spatial:
                    break  # OutC cannot fuse (NT undefined)
                halos = halo_growth(layers[i:b + 1], b - i)
                if b > i and 2 * halos[0] >= min_shard_extent(
                        layers[i], p, tb.nodes):
                    stats.pruned_halo += 1
                    break  # halo degenerated into replication
                segcost = 0.0
                for off, m in enumerate(range(i, b + 1)):
                    segcost += est.i_cost(layers[m], p, tb,
                                          extra_halo=halos[off] if b > i else 0)
                    stats.i_calls += 1
                if segcost >= best:
                    stats.pruned_threshold += 1
                    break  # dynamic threshold: monotone in b
                if b == n - 1:
                    stats.s_calls += 1
                    c = segcost + est.s_cost(layers[b], None, p, None, tb)
                    if c < best:
                        best, best_choice = c, (b, -1)
                else:
                    for qi, q in enumerate(schemes):
                        if S[b + 1][qi] == _INF:
                            continue
                        stats.s_calls += 1
                        c = (segcost
                             + est.s_cost(layers[b], layers[b + 1], p, q, tb)
                             + S[b + 1][qi])
                        if c < best:
                            best, best_choice = c, (b, qi)
            S[i][pi] = best
            choice[i][pi] = best_choice

    pi = min(range(k), key=lambda j: S[0][j])
    total = S[0][pi]
    steps: List[Tuple[Scheme, Mode]] = []
    i = 0
    while i < n:
        b, qi = choice[i][pi]
        p = schemes[pi]
        for m in range(i, b + 1):
            steps.append((p, Mode.NT if m < b else Mode.T))
        i = b + 1
        if qi >= 0:
            pi = qi
    return SearchResult(plan=Plan(tuple(steps)), cost=total, stats=stats)


def _dag_plan_search_reference(graph: ModelGraph, est: CostEstimator,
                               tb: Testbed, schemes: Tuple[Scheme, ...],
                               max_segment: int,
                               allow_fusion: bool) -> SearchResult:
    stats = SearchStats()
    layers = graph.layers

    def icost(l, p, halo=0):
        stats.i_calls += 1
        return est.i_cost(l, p, tb, extra_halo=halo)

    def scost(l, nxt, s, d):
        stats.s_calls += 1
        return est.s_cost(l, nxt, s, d, tb)

    branches = graph.linearize()

    def btable(t: int, head_solo: bool):
        ls = [layers[i] for i in branches[t].ids]
        return _scalar_chain_tables(ls, icost, scost, schemes, max_segment,
                                    allow_fusion, head_solo, tb.nodes, stats)

    def jscost(prod: int, cons: Optional[int], pi: int,
               qi: Optional[int]) -> float:
        return scost(layers[prod], None if cons is None else layers[cons],
                     schemes[pi], None if qi is None else schemes[qi])

    return _dag_compose(graph, schemes, btable, jscost, stats)
