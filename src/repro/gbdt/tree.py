"""Histogram-based regression tree — the weak learner of our GBDT.

A from-scratch, numpy-only stand-in for XGBoost (offline container).  Uses
the standard second-order gain with L2 regularization:

    gain = 1/2 * [ GL^2/(HL+lam) + GR^2/(HR+lam) - G^2/(H+lam) ] - gamma

For squared error, g = (pred - y), h = 1.  Features are pre-binned into
``n_bins`` quantile bins once per GBDT fit; split search is a single
histogram pass per (node, feature).

Inference is vectorized: the ``_Node`` list is flattened into parallel
numpy arrays (feature / threshold / left / right / value / is_leaf) and a
whole ``(n, d)`` feature matrix descends the tree in lockstep — the same
structure-of-arrays layout real histogram-GBDT engines use.  The flat
arrays also expose the tree to the forest-level batched predictor in
``gbdt.py``.  ``predict_reference`` keeps the one-sample-at-a-time walk as
the exactness oracle.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class _Node:
    feature: int = -1
    threshold: float = 0.0     # raw-value threshold (go left if x <= thr)
    left: int = -1
    right: int = -1
    value: float = 0.0
    is_leaf: bool = True


#: Flat structure-of-arrays form of a fitted tree:
#: (feature i32, threshold f64, left i32, right i32, value f64, is_leaf bool)
FlatTree = Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray,
                 np.ndarray]


def flatten_nodes(nodes: List[_Node]) -> FlatTree:
    feature = np.fromiter((n.feature for n in nodes), np.int32, len(nodes))
    threshold = np.fromiter((n.threshold for n in nodes), np.float64,
                            len(nodes))
    left = np.fromiter((n.left for n in nodes), np.int32, len(nodes))
    right = np.fromiter((n.right for n in nodes), np.int32, len(nodes))
    value = np.fromiter((n.value for n in nodes), np.float64, len(nodes))
    is_leaf = np.fromiter((n.is_leaf for n in nodes), np.bool_, len(nodes))
    return feature, threshold, left, right, value, is_leaf


class RegressionTree:
    def __init__(self, max_depth: int = 6, min_child_weight: float = 2.0,
                 reg_lambda: float = 1.0, gamma: float = 0.0):
        self.max_depth = max_depth
        self.min_child_weight = min_child_weight
        self.reg_lambda = reg_lambda
        self.gamma = gamma
        self.nodes: List[_Node] = []
        self._flat: Optional[FlatTree] = None

    # binned: (n, d) int32 bin indices; edges: list of per-feature bin edges
    def fit(self, binned: np.ndarray, edges: List[np.ndarray],
            grad: np.ndarray, hess: np.ndarray) -> "RegressionTree":
        self.nodes = []
        self._flat = None
        idx = np.arange(binned.shape[0])
        self._build(binned, edges, grad, hess, idx, 0)
        return self

    def flat(self) -> FlatTree:
        """Structure-of-arrays view of the fitted tree (cached)."""
        if getattr(self, "_flat", None) is None or \
                len(self._flat[0]) != len(self.nodes):
            self._flat = flatten_nodes(self.nodes)
        return self._flat

    def _leaf_value(self, g: float, h: float) -> float:
        return -g / (h + self.reg_lambda)

    def _build(self, binned, edges, grad, hess, idx, depth) -> int:
        node_id = len(self.nodes)
        self.nodes.append(_Node())
        g_sum = float(grad[idx].sum())
        h_sum = float(hess[idx].sum())
        node = self.nodes[node_id]
        node.value = self._leaf_value(g_sum, h_sum)
        if depth >= self.max_depth or h_sum < 2 * self.min_child_weight \
                or len(idx) < 2:
            return node_id

        best_gain, best_f, best_bin = 0.0, -1, -1
        parent_score = g_sum * g_sum / (h_sum + self.reg_lambda)
        xb = binned[idx]
        gi, hi = grad[idx], hess[idx]
        for f in range(binned.shape[1]):
            nb = len(edges[f]) + 1
            if nb <= 1:
                continue
            gh = np.zeros(nb)
            hh = np.zeros(nb)
            np.add.at(gh, xb[:, f], gi)
            np.add.at(hh, xb[:, f], hi)
            gl = np.cumsum(gh)[:-1]
            hl = np.cumsum(hh)[:-1]
            gr = g_sum - gl
            hr = h_sum - hl
            valid = (hl >= self.min_child_weight) & (hr >= self.min_child_weight)
            if not valid.any():
                continue
            gains = (gl * gl / (hl + self.reg_lambda)
                     + gr * gr / (hr + self.reg_lambda) - parent_score)
            gains = np.where(valid, gains, -np.inf)
            b = int(np.argmax(gains))
            if gains[b] > best_gain + 2 * self.gamma:
                best_gain, best_f, best_bin = float(gains[b]), f, b

        if best_f < 0:
            return node_id

        go_left = xb[:, best_f] <= best_bin
        li, ri = idx[go_left], idx[~go_left]
        node.is_leaf = False
        node.feature = best_f
        node.threshold = float(edges[best_f][best_bin])
        node.left = self._build(binned, edges, grad, hess, li, depth + 1)
        node.right = self._build(binned, edges, grad, hess, ri, depth + 1)
        return node_id

    def predict(self, x: np.ndarray) -> np.ndarray:
        """Vectorized prediction: all rows descend the flat-array tree in
        lockstep (one gather + one compare per depth level)."""
        feature, threshold, left, right, value, is_leaf = self.flat()
        n = x.shape[0]
        cur = np.zeros(n, dtype=np.int32)
        live = np.flatnonzero(~is_leaf[cur])
        while live.size:
            c = cur[live]
            go_left = x[live, feature[c]] <= threshold[c]
            cur[live] = np.where(go_left, left[c], right[c])
            live = live[~is_leaf[cur[live]]]
        return value[cur]

    def predict_reference(self, x: np.ndarray) -> np.ndarray:
        """Scalar per-sample tree walk — the parity oracle for ``predict``."""
        out = np.zeros(x.shape[0])
        for i in range(x.shape[0]):
            node = self.nodes[0]
            while not node.is_leaf:
                node = self.nodes[node.left
                                  if x[i, node.feature] <= node.threshold
                                  else node.right]
            out[i] = node.value
        return out
