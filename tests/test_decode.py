"""Autoregressive decode: paged-KV kernel conformance, the distributed
KV cache, and sharded decode equivalence against the single-device
oracle.

Acceptance contract: greedy decode through a *searched* plan
(``plan_decode`` — head-sharded OutC on every ATTN step, not a
hand-written plan) is token-for-token identical to ``reference_decode``
at nodes 2/4/8 on both executors.  The mesh-executor half follows the
repo's multi-device convention: the main process keeps jax at 1 device,
so real-mesh runs happen in an 8-fake-device subprocess (``slow``).
"""
import math
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ConvT, LayerSpec, Scheme, Testbed
from repro.kernels.flash_attention import flash_decode_paged
from repro.runtime.decode import (DecodeSession, TransformerSpec,
                                  decode_graph, greedy_decode,
                                  init_transformer, plan_decode,
                                  prefill_graph, reference_decode)
from repro.runtime.kv_cache import PagedKVCache
from repro.runtime.session import ExecConfig

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: head-sharding-friendly testbed: SRIO-class latency makes the decode
#: gather cheap enough that OutC wins at every node count (cf. the
#: latency-dominated default where 8-node decode prefers replication)
TB = lambda nodes: Testbed(nodes=nodes, bandwidth_gbps=5.0,
                           link_latency_us=1.0)

SPEC = TransformerSpec(n_layers=2, d_model=256, n_heads=8, d_ff=1024,
                       vocab=64)
PROMPT = [3, 17, 42, 7]
N_NEW = 5


def _searched_plan(nodes, spec=SPEC, kv_len=2048):
    res = plan_decode(spec, kv_len, nodes, tb=TB(nodes))
    # the acceptance bar: the planner itself must choose head sharding
    attn = [s for i, (s, _) in enumerate(res.plan.steps) if i % 2 == 0]
    assert all(s == Scheme.OUTC for s in attn), attn
    return res.plan


@pytest.fixture(scope="module")
def oracle():
    w = init_transformer(SPEC, seed=1)
    toks, lg = reference_decode(SPEC, w, PROMPT, N_NEW)
    return w, toks, lg


# ---------------------------------------------------------------------------
# decode kernel conformance (q_len == 1 over a paged table)
# ---------------------------------------------------------------------------

def _decode_ref(q, k, v, kv_len, window):
    """Inline softmax reference over contiguous logical-order K/V."""
    hd = q.shape[-1]
    s = np.einsum("bd,btd->bt", q, k[:, :kv_len]) / math.sqrt(hd)
    if window is not None:
        t = np.arange(kv_len)
        s = np.where(t[None, :] > kv_len - 1 - window, s, -np.inf)
    s = s - s.max(-1, keepdims=True)
    p = np.exp(s)
    p /= p.sum(-1, keepdims=True)
    return np.einsum("bt,btd->bd", p, v[:, :kv_len])


@pytest.mark.parametrize("window", [None, 6, 2])
@pytest.mark.parametrize("kv_len", [1, 4, 7, 13, 20])
def test_flash_decode_paged_conformance(window, kv_len):
    """Scrambled page table, partial last page, sliding windows whose
    lower bound lands mid-page: the kernel must floor its block skip to
    the page boundary (a mid-page start would walk the wrong physical
    page) and mask in-page, matching the contiguous reference."""
    rng = np.random.default_rng(kv_len * 31 + (window or 0))
    BH, ps, hd = 3, 4, 8
    n_pages = 5
    assert kv_len <= n_pages * ps
    k = rng.normal(size=(BH, n_pages * ps, hd)).astype(np.float32)
    v = rng.normal(size=(BH, n_pages * ps, hd)).astype(np.float32)
    q = rng.normal(size=(BH, hd)).astype(np.float32)
    table = rng.permutation(n_pages).astype(np.int32)
    kp = np.zeros((BH, n_pages, ps, hd), np.float32)
    vp = np.zeros_like(kp)
    for lp in range(n_pages):
        kp[:, table[lp]] = k[:, lp * ps:(lp + 1) * ps]
        vp[:, table[lp]] = v[:, lp * ps:(lp + 1) * ps]
    out = flash_decode_paged(jnp.asarray(q), jnp.asarray(kp),
                             jnp.asarray(vp), table, kv_len, window=window)
    ref = _decode_ref(q, k, v, kv_len, window)
    assert float(np.max(np.abs(np.asarray(out) - ref))) < 1e-5


# ---------------------------------------------------------------------------
# distributed paged KV cache
# ---------------------------------------------------------------------------

def test_paged_cache_scrambled_table_roundtrip():
    cache = PagedKVCache([[2, 1]], head_dim=3, page_size=4, capacity=16,
                         seed=3)
    table = cache.page_table
    assert sorted(table.tolist()) == list(range(4))
    assert table.tolist() != list(range(4))   # genuinely scrambled
    rng = np.random.default_rng(0)
    ks = {0: [], 1: []}
    for pos in range(7):
        for node, lh in enumerate((2, 1)):
            k = jnp.asarray(rng.normal(size=(lh, 3)), jnp.float32)
            cache.append(0, node, pos, k, 2.0 * k)
            ks[node].append(np.asarray(k))
        cache.advance()
    assert cache.length == 7
    for node in (0, 1):
        k, v = cache.gather(0, node)
        assert k.shape == (7, (2, 1)[node], 3)
        np.testing.assert_allclose(np.asarray(k), np.stack(ks[node]))
        np.testing.assert_allclose(np.asarray(v),
                                   2.0 * np.stack(ks[node]))


def test_paged_cache_bytes_follow_head_ownership():
    """Pages live on head owners: a node owning 3x the heads holds 3x
    the bytes; a replicated layer costs full pool on every node."""
    sharded = PagedKVCache([[3, 1]], head_dim=4, page_size=2, capacity=8)
    assert sharded.bytes_per_node(0) == 3 * sharded.bytes_per_node(1)
    repl = PagedKVCache([[4, 4]], head_dim=4, page_size=2, capacity=8)
    assert repl.bytes_per_node(0) == repl.bytes_per_node(1)
    assert repl.bytes_per_node(1) == sharded.bytes_per_node(0) \
        + sharded.bytes_per_node(1)


def test_paged_cache_overflow_and_bounds():
    cache = PagedKVCache([[1]], head_dim=2, page_size=2, capacity=4)
    cache.advance(4)
    with pytest.raises(ValueError, match="overflow"):
        cache.advance(1)
    with pytest.raises(ValueError, match="capacity"):
        cache.slot(4)
    with pytest.raises(ValueError, match="pool shape"):
        cache.store(0, 0, jnp.zeros((2, 2, 2, 2)), jnp.zeros((2, 2, 2, 2)))


# ---------------------------------------------------------------------------
# IR: ATTN/FFN layers and the decode graphs
# ---------------------------------------------------------------------------

def test_attn_ir_validation():
    from repro.core.graph import chain
    ok = LayerSpec("a", ConvT.ATTN, 1, 1, 32, 32, heads=4)
    assert ok.heads == 4 and ok.flops() > 0
    with pytest.raises(ValueError, match="heads"):
        TransformerSpec(1, 32, 5, 64)          # 32 % 5 != 0
    with pytest.raises(ValueError, match="heads"):
        chain("bad", [LayerSpec("a", ConvT.ATTN, 1, 1, 32, 32, heads=3)])
    with pytest.raises(ValueError, match="head"):
        chain("bad", [LayerSpec("a", ConvT.FC, 1, 1, 32, 32, heads=4)])


def test_decode_graph_structure():
    g = decode_graph(SPEC, kv_len=512)
    assert len(g) == 2 * SPEC.n_layers
    for i, l in enumerate(g.layers):
        assert l.in_h == 1 and l.in_w == 1
        if i % 2 == 0:
            assert l.conv_t == ConvT.ATTN and l.heads == SPEC.n_heads
            # folded score/value matmuls grow with kv_len
            assert l.extra_flop_factor == pytest.approx(
                4.0 + 2.0 * 512 / SPEC.d_model)
        else:
            assert l.conv_t == ConvT.FFN and l.heads == 0
    p = prefill_graph(SPEC, seq_len=64)
    assert all(l.in_h == 64 for l in p.layers)


@pytest.mark.parametrize("nodes", [2, 4, 8])
def test_plan_search_head_shards_decode(nodes):
    """The planner head-shards decode on its own: every ATTN step OutC."""
    _searched_plan(nodes)


# ---------------------------------------------------------------------------
# sharded decode == single-device oracle (local executor, in-process)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nodes", [1, 2, 4, 8])
def test_decode_local_equivalence(oracle, nodes):
    w, ref_toks, ref_lg = oracle
    plan = _searched_plan(max(nodes, 2))
    sess = DecodeSession(SPEC, w, plan, nodes, ExecConfig(),
                         page_size=4, capacity=32)
    toks, lg = greedy_decode(sess, PROMPT, N_NEW)
    assert toks == ref_toks
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref_lg),
                               rtol=1e-4, atol=1e-4)
    # pages really live on head owners for the searched (sharded) plan
    if nodes > 1:
        assert all(sess.cache.bytes_per_node(n)
                   < sess.cache.bytes_per_node(0) * nodes
                   for n in range(nodes))
    assert sess.cache.length == len(PROMPT) + N_NEW


def test_decode_local_pallas_backend(oracle):
    """The paged Pallas decode kernel slots into the same step program."""
    w, ref_toks, ref_lg = oracle
    sess = DecodeSession(SPEC, w, _searched_plan(4), 4,
                         ExecConfig(backend="pallas"),
                         page_size=4, capacity=32)
    toks, lg = greedy_decode(sess, PROMPT, N_NEW)
    assert toks == ref_toks
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref_lg),
                               rtol=1e-4, atol=1e-4)


def test_decode_mixed_plan_replicated_layers(oracle):
    """Non-OutC steps run replicated and still match (the DP may mix)."""
    from repro.core.plan import Mode, Plan
    w, ref_toks, ref_lg = oracle
    plan = Plan(((Scheme.INH, Mode.T), (Scheme.OUTC, Mode.T),
                 (Scheme.OUTC, Mode.T), (Scheme.INH, Mode.T)))
    sess = DecodeSession(SPEC, w, plan, 4, ExecConfig(),
                         page_size=4, capacity=32)
    toks, lg = greedy_decode(sess, PROMPT, N_NEW)
    assert toks == ref_toks
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref_lg),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# property: paged+sharded == contiguous single-device, random geometry
# (hypothesis when installed, PR-7-style fixed-seed slice otherwise)
# ---------------------------------------------------------------------------

def _property_case(seed):
    rng = np.random.default_rng(seed)
    H = int(rng.choice([1, 2, 4, 6]))
    hd = int(rng.choice([4, 8]))
    spec = TransformerSpec(n_layers=int(rng.integers(1, 3)),
                           d_model=H * hd, n_heads=H,
                           d_ff=int(rng.choice([16, 32])), vocab=32)
    w = init_transformer(spec, seed=seed)
    page_size = int(rng.integers(1, 6))
    prompt = [int(t) for t in rng.integers(0, spec.vocab, rng.integers(1, 6))]
    n_new = int(rng.integers(1, 5))
    nodes = int(rng.integers(1, 5))
    total = len(prompt) + n_new
    ref_toks, ref_lg = reference_decode(spec, w, prompt, n_new)
    from repro.core.plan import Mode, Plan
    steps = []
    for _ in range(spec.n_layers):
        steps.append((Scheme.OUTC if rng.random() < 0.75 else Scheme.INH,
                      Mode.T))
        steps.append((Scheme.OUTC if rng.random() < 0.5 else Scheme.INH,
                      Mode.T))
    sess = DecodeSession(spec, w, Plan(tuple(steps)), nodes, ExecConfig(),
                         page_size=page_size,
                         capacity=total + int(rng.integers(0, 7)),
                         cache_seed=seed + 1)
    toks, lg = greedy_decode(sess, prompt, n_new)
    assert toks == ref_toks, (seed, spec)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref_lg),
                               rtol=1e-4, atol=1e-4)


try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:        # property tests only; see pyproject [dev]
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @settings(max_examples=12, deadline=None)
    @given(st.integers(0, 2 ** 16))
    def test_property_paged_sharded_decode(seed):
        _property_case(seed)
else:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3, 5, 8, 13, 21])
    def test_property_paged_sharded_decode(seed):
        _property_case(seed)


# ---------------------------------------------------------------------------
# serving: prefill/decode split + continuous decode-step batching
# ---------------------------------------------------------------------------

def test_serve_decode_split_plans_differ_by_phase():
    """The split is real: decode head-shards (OutC), prefill — compute
    bound over seq — picks a spatial scheme."""
    from repro.cluster import homogeneous, plan_decode_serving
    cl = homogeneous(4, bandwidth_gbps=5.0)
    pre, dec = plan_decode_serving(SPEC, cl, prompt_len=64, n_new=16)
    assert all(s == Scheme.OUTC for i, (s, _) in
               enumerate(dec.plan.steps) if i % 2 == 0)
    assert any(s.spatial for s, _ in pre.plan.steps)


def test_serve_decode_continuous_batching():
    from repro.cluster import homogeneous, serve_decode
    cl = homogeneous(4, bandwidth_gbps=5.0)
    kw = dict(prompt_len=64, n_new=16, n_requests=24, max_batch=8)
    slow = serve_decode(SPEC, cl, arrival_rate_rps=2.0, **kw)
    fast = serve_decode(SPEC, cl, arrival_rate_rps=2000.0, **kw)
    # saturation batches decode steps; trickle arrivals decode solo
    assert slow.mean_batch == pytest.approx(1.0)
    assert fast.mean_batch > 2.0
    assert fast.tokens_per_s > slow.tokens_per_s
    assert fast.p99_latency_s >= fast.p50_latency_s > 0.0
    assert slow.prefill_s > slow.decode_step_s > 0.0
    with pytest.raises(ValueError, match="arrival rate"):
        serve_decode(SPEC, cl, arrival_rate_rps=0.0, **kw)


# ---------------------------------------------------------------------------
# mesh executor (subprocess, 8 fake devices)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_decode_mesh_equivalence():
    """Token-for-token identical on a real device mesh at nodes 2/4/8
    (xla backend) plus a pallas spot-check — searched plans only."""
    code = textwrap.dedent("""
        import numpy as np, jax
        assert len(jax.devices()) == 8, jax.devices()
        from repro.core.cost import Testbed
        from repro.core.partition import Scheme
        from repro.runtime.decode import (DecodeSession, TransformerSpec,
            greedy_decode, init_transformer, plan_decode, reference_decode)
        from repro.runtime.session import ExecConfig

        spec = TransformerSpec(n_layers=2, d_model=256, n_heads=8,
                               d_ff=1024, vocab=64)
        w = init_transformer(spec, seed=1)
        prompt, n_new = [3, 17, 42, 7], 5
        ref_toks, ref_lg = reference_decode(spec, w, prompt, n_new)
        for nodes, backend in ((2, "xla"), (4, "xla"), (8, "xla"),
                               (2, "pallas")):
            tb = Testbed(nodes=nodes, bandwidth_gbps=5.0,
                         link_latency_us=1.0)
            plan = plan_decode(spec, 2048, nodes, tb=tb).plan
            assert all(s == Scheme.OUTC for i, (s, _) in
                       enumerate(plan.steps) if i % 2 == 0)
            sess = DecodeSession(spec, w, plan, nodes,
                                 ExecConfig(executor="mesh",
                                            backend=backend),
                                 page_size=4, capacity=32)
            toks, lg = greedy_decode(sess, prompt, n_new)
            assert toks == ref_toks, (nodes, backend, toks)
            err = float(np.max(np.abs(np.asarray(lg) -
                                      np.asarray(ref_lg))))
            assert err < 1e-3, (nodes, backend, err)
            print("MESH_DECODE_OK", nodes, backend)
        print("ALL_MESH_DECODE_OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=1200)
    assert "ALL_MESH_DECODE_OK" in r.stdout, r.stdout + r.stderr
