"""From-scratch GBDT: regression quality, persistence, estimator loop."""
import numpy as np

from repro.gbdt import GBDTRegressor


def _toy(n=4000, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.uniform(-2, 2, size=(n, 5))
    y = (np.sin(x[:, 0]) + 0.5 * x[:, 1] ** 2 + (x[:, 2] > 0) * x[:, 3]
         + 0.05 * rng.normal(size=n))
    return x, y


def test_gbdt_fits_nonlinear_function():
    x, y = _toy()
    xt, yt = _toy(seed=1)
    m = GBDTRegressor(n_estimators=80, learning_rate=0.2, max_depth=5)
    m.fit(x, y)
    pred = m.predict(xt)
    ss_res = np.sum((pred - yt) ** 2)
    ss_tot = np.sum((yt - yt.mean()) ** 2)
    r2 = 1 - ss_res / ss_tot
    assert r2 > 0.9, r2


def test_gbdt_save_load_roundtrip(tmp_path):
    x, y = _toy(1000)
    m = GBDTRegressor(n_estimators=20, max_depth=4).fit(x, y)
    p = str(tmp_path / "model.npz")
    m.save(p)
    m2 = GBDTRegressor.load(p)
    np.testing.assert_allclose(m.predict(x[:50]), m2.predict(x[:50]),
                               rtol=1e-12)


def test_gbdt_monotone_improvement():
    x, y = _toy(2000)
    errs = []
    for n in (5, 20, 60):
        m = GBDTRegressor(n_estimators=n, max_depth=4, subsample=1.0).fit(x, y)
        errs.append(float(np.mean((m.predict(x) - y) ** 2)))
    assert errs[0] > errs[1] > errs[2]


def test_tree_vectorized_predict_bit_matches_reference():
    """The flat-array lockstep traversal lands in exactly the scalar
    walk's leaves on every tree of a fitted forest."""
    x, y = _toy(1500, seed=4)
    m = GBDTRegressor(n_estimators=15, max_depth=6).fit(x, y)
    xt, _ = _toy(700, seed=5)
    for tree in m.trees_:
        assert np.array_equal(tree.predict(xt), tree.predict_reference(xt))


def test_forest_vectorized_predict_bit_matches_reference():
    x, y = _toy(1500, seed=6)
    m = GBDTRegressor(n_estimators=25, max_depth=5).fit(x, y)
    xt, _ = _toy(400, seed=7)
    assert np.array_equal(m.predict(xt), m.predict_reference(xt))
    # single row (the scalar estimator path) and empty batch
    assert np.array_equal(m.predict(xt[:1]), m.predict_reference(xt[:1]))
    assert m.predict(xt[:0]).shape == (0,)


def test_forest_predict_exact_after_save_load(tmp_path):
    x, y = _toy(800, seed=8)
    m = GBDTRegressor(n_estimators=10, max_depth=4).fit(x, y)
    p = str(tmp_path / "m.npz")
    m.save(p)
    m2 = GBDTRegressor.load(p)
    xt, _ = _toy(300, seed=9)
    assert np.array_equal(m2.predict(xt), m2.predict_reference(xt))


def test_gbdt_estimator_batch_bit_matches_scalar():
    """GBDTEstimator.i_cost_batch / s_cost_batch equal the scalar protocol
    exactly (one exp(predict) per row either way)."""
    from repro.core import GBDTEstimator, Scheme, Testbed
    from repro.core.estimator import i_features, s_features
    from repro.sim.trace import TraceConfig, _random_layer, _random_testbed

    rng = np.random.default_rng(11)
    xi = rng.uniform(0, 200, size=(1200, 16))
    xs = rng.uniform(0, 200, size=(1200, 18))
    est = GBDTEstimator(
        GBDTRegressor(n_estimators=10, max_depth=4).fit(xi, rng.normal(size=1200)),
        GBDTRegressor(n_estimators=10, max_depth=4).fit(xs, rng.normal(size=1200)))
    cfg = TraceConfig()
    irows, srows, i_want, s_want = [], [], [], []
    for _ in range(100):
        layer = _random_layer(rng)
        tb = _random_testbed(rng, cfg)
        sch = Scheme(int(rng.integers(0, 4)))
        halo = int(rng.integers(0, 4)) if sch.spatial else 0
        irows.append(i_features(layer, sch, tb, halo))
        i_want.append(est.i_cost(layer, sch, tb, extra_halo=halo))
        nxt = _random_layer(rng)
        dst = Scheme(int(rng.integers(0, 4)))
        srows.append(s_features(layer, nxt, sch, dst, tb))
        s_want.append(est.s_cost(layer, nxt, sch, dst, tb))
    assert np.array_equal(est.i_cost_batch(np.asarray(irows), Testbed()),
                          np.asarray(i_want))
    assert np.array_equal(est.s_cost_batch(np.asarray(srows), Testbed()),
                          np.asarray(s_want))


def test_estimator_training_end_to_end():
    """Traces -> GBDT -> DPP: plan must stay near the analytic optimum."""
    from repro.core import AnalyticEstimator, Testbed
    from repro.core.dpp import plan_search
    from repro.core.plan import plan_cost
    from repro.configs.edge_models import mobilenet_v1
    from repro.sim import TraceConfig, train_estimators

    est = train_estimators(TraceConfig(n_samples=4000, seed=3),
                           gbdt_kwargs=dict(n_estimators=40, max_depth=6))
    g = mobilenet_v1()
    tb = Testbed(nodes=4, bandwidth_gbps=1.0)
    gbdt_plan = plan_search(g, est, tb).plan
    true_cost = plan_cost(g, gbdt_plan, AnalyticEstimator(), tb)
    opt = plan_search(g, AnalyticEstimator(), tb).cost
    assert true_cost <= opt * 1.30   # within 30% of optimal (small GBDT)
