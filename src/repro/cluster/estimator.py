"""Cluster-bound analytic cost estimator (batched protocol).

``ClusterAnalyticEstimator`` is the heterogeneous counterpart of
``repro.core.AnalyticEstimator``: i-costs are straggler times over
capability-weighted per-device compute (``core.cost.hetero_compute_time_s``),
s-costs are the busiest-link bound over the cluster's per-edge graph
(``sync_time_s`` against the bottleneck-projected compat testbed).  It
implements the full batched protocol, so ``plan_search`` and the PR-2 cost
tables drive it through one ``i_cost_batch``/``s_cost_batch`` pair — no
scalar fallback on heterogeneous layouts.

``weighted=False`` keeps the same silicon but shards evenly (uniform
weights), which is the homogeneous-assumption baseline the sweep compares
capability-weighted plans against: even splits leave the slow device
straggling on every layer.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.cost import (Testbed, hetero_compute_time_batch_s,
                             hetero_compute_time_s, hetero_device_times_s,
                             sync_time_batch_s, sync_time_s)
from repro.core.estimator import (GBDTEstimator, N_HETERO_FEATURES,
                                  hetero_summary, i_features, s_features)
from repro.core.graph import LayerSpec
from repro.core.partition import Scheme

from .spec import ClusterSpec


class ClusterAnalyticEstimator:
    """Analytic CE bound to one :class:`ClusterSpec`.

    The ``tb`` argument of the estimator protocol must agree with the
    cluster's node count (pass ``cluster.compat_testbed()`` to the planner);
    scheme efficiencies / bottleneck link always come from the cluster.
    """

    def __init__(self, cluster: ClusterSpec, weighted: bool = True):
        self.cluster = cluster
        self.weighted = weighted
        self._tb = cluster.compat_testbed()
        self._speeds = cluster.speeds_gflops
        self._derates = cluster.dev_derates
        self._weights = (cluster.capability_weights if weighted
                         else (1.0,) * cluster.n)

    def _check(self, tb: Testbed) -> None:
        if tb != self._tb:
            raise ValueError(
                f"testbed {tb} does not match the cluster projection "
                f"{self._tb}; pass cluster.compat_testbed() to the planner "
                f"(for what-if sweeps, modify the ClusterSpec, not the "
                f"testbed)")

    # ---- scalar protocol --------------------------------------------------
    def i_cost(self, layer: LayerSpec, scheme: Scheme, tb: Testbed,
               extra_halo: int = 0) -> float:
        self._check(tb)
        return hetero_compute_time_s(layer, scheme, self._tb, self._speeds,
                                     self._derates, self._weights,
                                     extra_halo=extra_halo)

    def s_cost(self, layer: LayerSpec, nxt: Optional[LayerSpec], src: Scheme,
               dst: Optional[Scheme], tb: Testbed) -> float:
        self._check(tb)
        return sync_time_s(layer, nxt, src, dst, self._tb)

    # ---- batched protocol -------------------------------------------------
    def i_cost_batch(self, X: np.ndarray, tb: Testbed,
                     flop_factor: Optional[np.ndarray] = None) -> np.ndarray:
        self._check(tb)
        return hetero_compute_time_batch_s(
            X, self._tb, np.asarray(self._speeds),
            np.asarray(self._derates), np.asarray(self._weights),
            flop_factor)

    def s_cost_batch(self, X: np.ndarray, tb: Testbed) -> np.ndarray:
        self._check(tb)
        return sync_time_batch_s(X, self._tb)

    # ---- simulator hooks --------------------------------------------------
    def device_times(self, layer: LayerSpec, scheme: Scheme,
                     extra_halo: int = 0) -> np.ndarray:
        """Per-device compute seconds (straggler max == :meth:`i_cost`)."""
        return hetero_device_times_s(layer, scheme, self._tb, self._speeds,
                                     self._derates, self._weights,
                                     extra_halo=extra_halo)


class ClusterGBDTEstimator:
    """Learned CE bound to one :class:`ClusterSpec` (batched protocol).

    Wraps a hetero-trained :class:`repro.core.GBDTEstimator` — forests fit
    on traces with the capability-summary columns
    (``sim.trace.hetero_trace_config``) — and appends **this** cluster's
    summary to every 17/20-column row the cost tables build, so
    ``plan_search`` and ``pipeline_frontier`` run on learned costs over
    mixed clusters with zero call-site changes: the first-class
    ``BatchedCostEstimator`` the frontier DP drives.

    ``calibration`` optionally attaches an online residual corrector
    (``cluster.calibrate.OnlineCalibrator``): predictions are multiplied
    by its current correction factors at call time — the straggler-side
    maximum of the per-device compute corrections for i-costs, the sync
    correction for s-costs (capability-weighted shards equalize per-device
    time by construction, so the post-correction straggler is the device
    with the largest correction factor).
    """

    def __init__(self, est: GBDTEstimator, cluster: ClusterSpec,
                 calibration: Optional[object] = None):
        self.base = est
        self.cluster = cluster
        self.calibration = calibration
        self._tb = cluster.compat_testbed()
        self._summary = np.asarray(
            hetero_summary(cluster.capability_weights,
                           [link.bandwidth_gbps for link in cluster.links],
                           cluster.max_latency_us), np.float64)
        width = getattr(est.i_model, "n_features_", None)
        if width is not None and width != 17 + N_HETERO_FEATURES:
            raise ValueError(
                f"i-forest was fit on {width} features, expected "
                f"{17 + N_HETERO_FEATURES} (train with a hetero trace "
                f"config — sim.trace.hetero_trace_config())")

    def _check(self, tb: Testbed) -> None:
        if tb != self._tb:
            raise ValueError(
                f"testbed {tb} does not match the cluster projection "
                f"{self._tb}; pass cluster.compat_testbed() to the planner")

    def _scales(self) -> tuple:
        cal = self.calibration
        if cal is None:
            return 1.0, 1.0
        return (float(np.max(np.asarray(cal.compute_scale, np.float64))),
                float(cal.sync_scale))

    def _extend(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X, np.float64)
        cols = np.broadcast_to(self._summary,
                               (len(X), self._summary.size))
        return np.concatenate([X, cols], axis=1)

    # ---- scalar protocol --------------------------------------------------
    def i_cost(self, layer: LayerSpec, scheme: Scheme, tb: Testbed,
               extra_halo: int = 0) -> float:
        self._check(tb)
        x = np.asarray([i_features(layer, scheme, self._tb, extra_halo,
                                   hetero=list(self._summary))], np.float64)
        return float(np.exp(self.base.i_model.predict(x)[0])) \
            * self._scales()[0]

    def s_cost(self, layer: LayerSpec, nxt: Optional[LayerSpec], src: Scheme,
               dst: Optional[Scheme], tb: Testbed) -> float:
        self._check(tb)
        x = np.asarray([s_features(layer, nxt, src, dst, self._tb,
                                   hetero=list(self._summary))], np.float64)
        return float(np.exp(self.base.s_model.predict(x)[0])) \
            * self._scales()[1]

    # ---- batched protocol -------------------------------------------------
    def i_cost_batch(self, X: np.ndarray, tb: Testbed,
                     flop_factor: Optional[np.ndarray] = None) -> np.ndarray:
        """One forest pass over the widened matrix (``flop_factor`` is not
        part of the learned feature expression and is ignored, as in the
        homogeneous ``GBDTEstimator``)."""
        self._check(tb)
        t = np.exp(self.base.i_model.predict(self._extend(X)))
        return t * self._scales()[0]

    def s_cost_batch(self, X: np.ndarray, tb: Testbed) -> np.ndarray:
        self._check(tb)
        t = np.exp(self.base.s_model.predict(self._extend(X)))
        return t * self._scales()[1]
