"""Pallas TPU flash attention (causal / sliding-window), MXU-aligned tiles.

TPU-native adaptation of the streaming-softmax algorithm: the score matrix
never leaves VMEM; q blocks of ``block_q`` rows stream over k/v blocks of
``block_k`` with the online max/sum rescaling.  Block shapes default to 128
— the MXU systolic dimension — and the kv stream is an in-kernel
``fori_loop`` so a q tile's working set is
``block_q*hd + 2*block_k*hd + block_q*block_k`` floats, comfortably inside
the ~16 MiB VMEM for hd <= 256.

Validated on CPU via ``interpret=True`` against ``ref.attention_ref`` (the
container has no TPU); the grid/BlockSpec structure is the TPU deployment
artifact.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, causal: bool,
                  window: Optional[int], block_q: int, block_k: int,
                  seq_len: int):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale              # [bq, hd]
    nk = seq_len // block_k

    q_idx = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)

    def body(j, carry):
        m, l, acc = carry
        kb = k_ref[0, pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        vb = v_ref[0, pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        k_idx = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        valid = jnp.ones((block_q, block_k), bool)
        if causal:
            valid &= k_idx <= q_idx
        if window is not None:
            valid &= k_idx > q_idx - window
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, vb, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    hd = q_ref.shape[-1]
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    a0 = jnp.zeros((block_q, hd), jnp.float32)
    # causal upper bound: kv blocks beyond the diagonal contribute nothing
    hi = nk if not causal else jnp.minimum(
        nk, ((qi + 1) * block_q + block_k - 1) // block_k)
    # sliding-window lower bound: block j is fully masked when its last key
    # (j+1)*block_k - 1 <= min_q - window, so start at the first block that
    # can reach the tile's earliest query
    lo = 0 if window is None else jnp.maximum(
        0, (qi * block_q - window) // block_k)
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def _decode_kernel(seq_ref, pt_ref, q_ref, kp_ref, vp_ref, o_ref, *,
                   scale: float, window: Optional[int], page_size: int):
    """Single-query (decode) attention over a paged KV cache.

    One grid step per batch*head.  The kv stream walks *logical* pages
    ``lo .. hi`` and maps each through the page table to its physical slot,
    so block skipping happens in logical page space: the sliding-window
    lower bound is floored to the page boundary containing the earliest
    live key (a mid-page start would read the wrong physical page — the
    table is per whole page), and the in-page positions outside the window
    or beyond ``kv_len`` are masked instead.
    """
    kv_len = seq_ref[0]
    q = q_ref[:].astype(jnp.float32) * scale              # [1, hd]
    hd = q_ref.shape[-1]
    col = jax.lax.broadcasted_iota(jnp.int32, (1, page_size), 1)

    def body(j, carry):
        m, l, acc = carry
        phys = pt_ref[j]
        kb = kp_ref[0, phys].astype(jnp.float32)          # [ps, hd]
        vb = vp_ref[0, phys].astype(jnp.float32)
        s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        k_idx = j * page_size + col                        # [1, ps]
        valid = k_idx < kv_len
        if window is not None:
            valid &= k_idx > kv_len - 1 - window
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, vb, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((1,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((1,), jnp.float32)
    a0 = jnp.zeros((1, hd), jnp.float32)
    hi = -(-kv_len // page_size)                           # occupied pages
    # window lower bound, floored to the containing page: the first logical
    # page holding key index kv_len - window (never past a page boundary)
    lo = 0 if window is None else jnp.maximum(
        0, (kv_len - window) // page_size)
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))
    o_ref[:] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_decode_paged(q: jnp.ndarray, k_pages: jnp.ndarray,
                       v_pages: jnp.ndarray, page_table: jnp.ndarray,
                       kv_len, *, window: Optional[int] = None,
                       scale: Optional[float] = None,
                       interpret: bool = True) -> jnp.ndarray:
    """Decode-step (``q_len == 1``) flash attention over a paged KV cache.

    ``q``: [BH, hd]; ``k_pages``/``v_pages``: [BH, n_phys_pages, page_size,
    hd] physical page pool; ``page_table``: [n_logical_pages] int32 mapping
    logical page ``i`` (keys ``i*ps .. (i+1)*ps - 1``) to its physical
    slot; ``kv_len``: number of live keys (traced — the compiled program is
    reused as the sequence grows).  Pages beyond ``ceil(kv_len/ps)`` are
    never touched, so the table may contain garbage there.
    """
    BH, n_pages, page_size, hd = k_pages.shape
    assert q.shape == (BH, hd), (q.shape, k_pages.shape)
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               page_size=page_size)
    from jax.experimental.pallas import tpu as pltpu
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(BH,),
        in_specs=[
            pl.BlockSpec((1, hd), lambda b, seq, pt: (b, 0)),
            pl.BlockSpec((1, n_pages, page_size, hd),
                         lambda b, seq, pt: (b, 0, 0, 0)),
            pl.BlockSpec((1, n_pages, page_size, hd),
                         lambda b, seq, pt: (b, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, hd), lambda b, seq, pt: (b, 0)),
    )
    seq = jnp.asarray([kv_len], jnp.int32)
    pt = jnp.asarray(page_table, jnp.int32)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BH, hd), q.dtype),
        interpret=interpret,
    )(seq, pt, q, k_pages, v_pages)


def flash_attention_bh(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                       causal: bool = True, window: Optional[int] = None,
                       scale: Optional[float] = None, block_q: int = 128,
                       block_k: int = 128,
                       interpret: bool = True) -> jnp.ndarray:
    """q/k/v: [BH, S, hd]; S must be a multiple of the block sizes (the
    public wrapper in ops.py pads)."""
    BH, S, hd = q.shape
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    grid = (BH, S // block_q)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, seq_len=S)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, S, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, S, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
