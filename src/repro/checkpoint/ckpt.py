"""Minimal pytree checkpointing: flattened key-paths -> one .npz file.

Good enough for single-host examples/tests; a production deployment would
swap in tensorstore/orbax behind the same two functions.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_pytree(tree: Any, path: str) -> None:
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "biufc":   # e.g. bfloat16 (void in numpy)
            arr = arr.astype(np.float32)
        flat[_path_str(kp)] = arr
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez_compressed(path, **flat)


def load_pytree(template: Any, path: str) -> Any:
    data = np.load(path)
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for kp, leaf in flat_t:
        key = _path_str(kp)
        if key not in data:
            raise KeyError(f"checkpoint missing {key}")
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        import jax.numpy as jnp
        leaves.append(jnp.asarray(arr).astype(leaf.dtype))
    return treedef.unflatten(leaves)
