"""Elastic clusters: membership state machine, plan-aware memory,
migration costing, and the incremental replanner's reuse ladder.

Covers the three layers of ``cluster.elastic``:

* **DeviceRegistry** — heartbeat/lease transitions (JOINING → LIVE →
  SUSPECT → DEAD, graceful LEFT), derate/link reports, the seed-template
  identity of ``cluster()`` while membership matches the seed, and the
  invalid-transition errors;
* **plan_device_bytes / migration_cost_s** — scheme-aware weight
  ownership (OutC shards, spatial replicates), name-matched survivor
  reuse, drain accounting;
* **ElasticPlanner** — warm-vs-scratch frontier parity, the reuse
  ladder (frontier cache / registration / s-rows / uniform rescale),
  rational keep-vs-migrate, memory enforcement (``CapacityError``);

plus the refine-loop convergence controls added alongside (``rel_tol``,
``on_oscillation``, untrusted-sample guard).
"""
import dataclasses

import numpy as np
import pytest

from repro.cluster import (CapacityError, ClusterSpec, DeviceRegistry,
                           DeviceSpec, DeviceState, ElasticPlanner,
                           LinkSpec, MembershipError, RefineOscillationError,
                           asym_uplink, cluster_pipeline_frontier,
                           migration_cost_s, mixed_fast_slow,
                           plan_device_bytes, plan_memory_ok,
                           refine_with_simulator, stepped)
from repro.core import ConvT, LayerSpec, Objective, Scheme, chain
from repro.core.partition import DTYPE_BYTES


def _toy_chain(h=20):
    return chain("toy", [
        LayerSpec("c0", ConvT.CONV, h, h, 3, 8, 3, 1, 1),
        LayerSpec("dw", ConvT.DWCONV, h, h, 8, 8, 3, 1, 1),
        LayerSpec("pw", ConvT.POINTWISE, h, h, 8, 16, 1, 1, 0),
        LayerSpec("c1", ConvT.CONV, h, h, 16, 16, 3, 2, 1),
        LayerSpec("c2", ConvT.CONV, h // 2, h // 2, 16, 8, 3, 1, 1),
    ])


# ---------------------------------------------------------------------------
# DeviceRegistry state machine
# ---------------------------------------------------------------------------

def test_registry_seeds_live_and_returns_template():
    # asymmetric per-edge links (one congested uplink, as in the
    # asym_uplink preset, but with the unique device names the registry
    # is keyed on)
    base = asym_uplink(4)
    cluster = dataclasses.replace(
        base, devices=tuple(dataclasses.replace(d, name=f"d{i}")
                            for i, d in enumerate(base.devices)))
    reg = DeviceRegistry.from_cluster(cluster)
    assert all(m.state is DeviceState.LIVE for m in reg.members())
    assert len(reg.live_members()) == 4
    # while membership == seed set, cluster() IS the seed (per-edge links
    # survive — a uniform re-projection would lose the slow uplink)
    assert reg.cluster() is cluster


def test_registry_rejects_duplicate_device_names():
    # asym_uplink's anonymous devices all share one name — a registry
    # keyed by DeviceSpec.name must refuse the second join rather than
    # silently alias two physical boards
    with pytest.raises(MembershipError):
        DeviceRegistry.from_cluster(asym_uplink(2))


def test_registry_ctor_validation():
    with pytest.raises(ValueError):
        DeviceRegistry(heartbeat_interval_s=0.0)
    with pytest.raises(ValueError):
        DeviceRegistry(suspect_misses=0)
    with pytest.raises(ValueError):
        DeviceRegistry(suspect_misses=3, dead_misses=2)


def test_registry_join_heartbeat_transitions():
    reg = DeviceRegistry(heartbeat_interval_s=1.0, suspect_misses=2,
                         dead_misses=4)
    ch = reg.join(DeviceSpec(name="a"), now=0.0)
    assert ch.new is DeviceState.JOINING
    assert reg.live_members() == ()          # JOINING is not plannable
    ch = reg.heartbeat("a", now=0.5)
    assert (ch.old, ch.new) == (DeviceState.JOINING, DeviceState.LIVE)
    # duplicate join of a non-dead member is a protocol error
    with pytest.raises(MembershipError):
        reg.join(DeviceSpec(name="a"), now=1.0)
    with pytest.raises(MembershipError):
        reg.heartbeat("ghost", now=1.0)


def test_registry_lease_suspect_then_dead_then_rejoin():
    reg = DeviceRegistry(heartbeat_interval_s=1.0, suspect_misses=2,
                         dead_misses=4)
    reg.join(DeviceSpec(name="a"), now=0.0)
    reg.join(DeviceSpec(name="b"), now=0.0)
    reg.heartbeat("a", now=0.0)
    reg.heartbeat("b", now=0.0)
    # b keeps heartbeating, a goes silent
    reg.heartbeat("b", now=2.5)
    changes = reg.tick(now=2.5)
    assert [(c.name, c.new) for c in changes] == \
        [("a", DeviceState.SUSPECT)]
    # SUSPECT is still plannable — eviction is the disruptive act
    assert {m.spec.name for m in reg.live_members()} == {"a", "b"}
    reg.heartbeat("b", now=4.5)
    changes = reg.tick(now=4.5)
    assert [(c.name, c.new) for c in changes] == [("a", DeviceState.DEAD)]
    assert {m.spec.name for m in reg.live_members()} == {"b"}
    # DEAD devices must rejoin before heartbeating
    with pytest.raises(MembershipError):
        reg.heartbeat("a", now=5.0)
    reg.join(DeviceSpec(name="a"), now=5.0)
    reg.heartbeat("a", now=5.0)
    assert reg.member("a").state is DeviceState.LIVE
    # a SUSPECT device that heartbeats again returns to LIVE
    reg.tick(now=7.6)
    assert reg.member("b").state is DeviceState.SUSPECT
    ch = reg.heartbeat("b", now=7.7)
    assert (ch.old, ch.new) == (DeviceState.SUSPECT, DeviceState.LIVE)


def test_registry_leave_is_immediate_and_empty_cluster_raises():
    cluster = stepped(2)
    reg = DeviceRegistry.from_cluster(cluster)
    names = [d.name for d in cluster.devices]
    reg.leave(names[0], now=1.0)
    assert reg.member(names[0]).state is DeviceState.LEFT
    assert len(reg.live_members()) == 1
    reg.leave(names[1], now=2.0)
    with pytest.raises(MembershipError):
        reg.cluster()


def test_registry_derate_and_link_factor_project_into_cluster():
    cluster = stepped(3)
    reg = DeviceRegistry.from_cluster(cluster)
    name = cluster.devices[0].name
    v0 = reg.version
    sig0 = reg.signature()
    reg.report_derate(name, 0.5, now=1.0)
    assert reg.version > v0 and reg.signature() != sig0
    proj = reg.cluster()
    assert proj.devices[0].eff_derate == pytest.approx(
        cluster.devices[0].eff_derate * 0.5)
    # capability weights shift toward the healthy devices
    assert proj.capability_weights[0] < cluster.capability_weights[0]
    # clearing the report restores the seed template identity
    reg.report_derate(name, 1.0, now=2.0)
    assert reg.cluster() is cluster
    reg.set_link_factor(0.5)
    assert reg.cluster().bottleneck_bw_gbps == pytest.approx(
        cluster.bottleneck_bw_gbps * 0.5)
    with pytest.raises(ValueError):
        reg.report_derate(name, 0.0, now=3.0)
    with pytest.raises(ValueError):
        reg.set_link_factor(-1.0)


def test_registry_flap_restores_template_identity():
    # depart + rejoin of the LAST device restores join order, so the
    # projection collapses back to the seed template — the state the
    # elastic planner's frontier cache keys on
    cluster = stepped(4)
    reg = DeviceRegistry.from_cluster(
        cluster, heartbeat_interval_s=1.0, dead_misses=2)
    victim = cluster.devices[-1]
    sig0 = reg.signature()
    for m in reg.members():
        if m.spec.name != victim.name:
            reg.heartbeat(m.spec.name, now=3.0)
    reg.tick(now=3.0)
    assert reg.member(victim.name).state is DeviceState.DEAD
    assert reg.signature() != sig0
    reg.join(victim, now=4.0)
    reg.heartbeat(victim.name, now=4.0)
    assert reg.signature() == sig0
    assert reg.cluster() is cluster


# ---------------------------------------------------------------------------
# plan-aware memory + migration geometry
# ---------------------------------------------------------------------------

def _fixed_plan(graph, scheme):
    from repro.core.plan import Plan
    from repro.core.partition import Mode
    return Plan(steps=tuple((scheme, Mode.T) for _ in graph.layers))


def test_plan_device_bytes_outc_shards_spatial_replicates():
    # weight-heavy chain (big pointwise banks, tiny maps) so filter
    # ownership dominates the activation peak
    g = chain("wide", [
        LayerSpec("p0", ConvT.POINTWISE, 4, 4, 64, 256, 1, 1, 0),
        LayerSpec("p1", ConvT.POINTWISE, 4, 4, 256, 256, 1, 1, 0),
        LayerSpec("p2", ConvT.POINTWISE, 4, 4, 256, 64, 1, 1, 0),
    ])
    cluster = stepped(4)
    total_w = sum(l.weight_elems() for l in g.layers) * DTYPE_BYTES
    outc = plan_device_bytes(g, _fixed_plan(g, Scheme.OUTC), cluster)
    inh = plan_device_bytes(g, _fixed_plan(g, Scheme.INH), cluster)
    # spatial: every device holds every filter bank
    assert all(float(b) >= total_w for b in inh)
    # OutC: the banks are partitioned by capability share — no device
    # holds the full set, and the fleet total is well under the
    # replicated fleet total
    assert all(float(b) < total_w for b in outc)
    assert float(outc.sum()) < float(inh.sum())


def test_plan_memory_ok_flags_small_devices():
    g = _toy_chain()
    cluster = stepped(4)
    tiny = dataclasses.replace(
        cluster,
        devices=tuple(dataclasses.replace(d, mem_mb=0.001)
                      for d in cluster.devices))
    assert all(plan_memory_ok(g, _fixed_plan(g, Scheme.INH), cluster))
    assert not any(plan_memory_ok(g, _fixed_plan(g, Scheme.INH), tiny))


def test_migration_cost_cold_start_and_survivor_reuse():
    g = _toy_chain()
    cluster = stepped(4)
    plan = _fixed_plan(g, Scheme.OUTC)
    cold = migration_cost_s(g, None, None, plan, cluster)
    assert cold.bytes_moved > 0 and cold.devices_touched == 4
    # same plan on the same survivors: nothing to move
    warm = migration_cost_s(g, plan, cluster, plan, cluster)
    assert warm.bytes_moved == 0.0 and warm.total_s == 0.0
    # drop the last device: survivors are matched by name, so only the
    # victim's vacated intervals travel — strictly less than cold start
    small = dataclasses.replace(
        cluster, devices=cluster.devices[:-1],
        links=cluster.links[:len(cluster.devices) - 1])
    plan_s = _fixed_plan(g, Scheme.OUTC)
    shrink = migration_cost_s(g, plan, cluster, plan_s, small)
    cold_s = migration_cost_s(g, None, None, plan_s, small)
    assert 0.0 < shrink.bytes_moved < cold_s.bytes_moved
    # spatial -> spatial keeps every replicated bank in place
    spat = migration_cost_s(g, _fixed_plan(g, Scheme.INH), cluster,
                            _fixed_plan(g, Scheme.INW), cluster)
    assert spat.bytes_moved == 0.0


def test_migration_cost_drain_term():
    g = _toy_chain()
    cluster = stepped(4)
    plan = _fixed_plan(g, Scheme.INH)
    m = migration_cost_s(g, plan, cluster, plan, cluster,
                         inflight=5, old_period_s=0.2)
    assert m.drain_s == pytest.approx(1.0)
    assert m.total_s == pytest.approx(m.move_s + 1.0)


# ---------------------------------------------------------------------------
# ElasticPlanner: reuse ladder + keep-vs-migrate
# ---------------------------------------------------------------------------

def test_planner_reuse_ladder_and_warm_scratch_parity():
    g = _toy_chain()
    cluster = stepped(4)
    reg = DeviceRegistry.from_cluster(cluster)
    pl = ElasticPlanner(g)
    d0 = pl.replan(reg.cluster())
    assert not any((d0.reuse["frontier_cache"], d0.reuse["registration"],
                    d0.reuse["svals"]))
    # uniform derate on every device scales all i-costs by one factor:
    # registration + s-rows reuse plus the exact rescale fast path
    for d in cluster.devices:
        reg.report_derate(d.name, 0.5, now=1.0)
    d1 = pl.replan(reg.cluster(), d0.plan, cluster,
                   old_period_s=d0.period_s, consider_keep=False)
    assert d1.reuse["registration"] and d1.reuse["svals"]
    assert d1.reuse["rescale"] == pytest.approx(2.0)
    # the rescaled frontier must equal a from-scratch build bit for bit
    fresh = ElasticPlanner(g)
    d1s = fresh.replan(reg.cluster(), consider_keep=False)
    np.testing.assert_allclose(np.sort(d1.frontier.points, axis=0),
                               np.sort(d1s.frontier.points, axis=0),
                               rtol=1e-12)
    assert d1.plan.steps == d1s.plan.steps
    # reverting restores the original signature: whole-frontier LRU hit
    for d in cluster.devices:
        reg.report_derate(d.name, 1.0, now=2.0)
    d2 = pl.replan(reg.cluster(), d1.plan, cluster, consider_keep=False)
    assert d2.reuse["frontier_cache"]
    assert d2.plan.steps == d0.plan.steps


def test_planner_keep_vs_migrate_rationality():
    g = _toy_chain()
    cluster = stepped(4)
    reg = DeviceRegistry.from_cluster(cluster)
    pl = ElasticPlanner(g, horizon_requests=500.0)
    d0 = pl.replan(reg.cluster())
    assert d0.migrate and d0.point_idx is not None
    # a trivial capability wobble: over a short horizon the migration
    # cannot pay for itself, so the old plan is kept...
    reg.report_derate(cluster.devices[0].name, 0.95, now=1.0)
    short = ElasticPlanner(g, horizon_requests=1e-6)
    short.replan(reg.cluster())  # prime caches (not required, just cheap)
    dk = short.replan(reg.cluster(), d0.plan, cluster,
                      old_period_s=d0.period_s)
    assert not dk.migrate and dk.plan.steps == d0.plan.steps
    assert dk.point_idx is None and dk.keep_score_s == dk.score_s
    # ...and consider_keep=False forces the frontier-best adoption
    df = short.replan(reg.cluster(), d0.plan, cluster,
                      old_period_s=d0.period_s, consider_keep=False)
    assert df.migrate and df.point_idx is not None
    assert df.keep_score_s is None
    # with an enormous horizon the better plan always wins: its score is
    # never worse than keeping (equal steps count as keep)
    long = ElasticPlanner(g, horizon_requests=1e9)
    dm = long.replan(reg.cluster(), d0.plan, cluster,
                     old_period_s=d0.period_s)
    assert dm.period_s <= d0.period_s + 1e-12


def test_planner_capacity_error_on_tiny_memory():
    g = _toy_chain()
    cluster = stepped(3)
    tiny = dataclasses.replace(
        cluster,
        devices=tuple(dataclasses.replace(d, mem_mb=0.001)
                      for d in cluster.devices))
    pl = ElasticPlanner(g)
    with pytest.raises(CapacityError):
        pl.replan(tiny)
    # enforce_memory=False plans anyway (advisory mode)
    loose = ElasticPlanner(g, enforce_memory=False)
    assert loose.replan(tiny).plan is not None


# ---------------------------------------------------------------------------
# refine-loop convergence controls
# ---------------------------------------------------------------------------

class _Occ:
    failures = 0

    def __init__(self, dev, link):
        self.dev_occupancy_s = dev
        self.link_occupancy_s = link
        self.period_s = max(dev, link)


def test_refine_oscillation_raises_when_asked():
    g = _toy_chain()
    cluster = stepped(4)
    calls = {"n": 0}

    def flip(plan):
        # alternately blame compute then sync: the reweighted selection
        # ping-pongs between the frontier's two ends — a genuine cycle
        calls["n"] += 1
        return (_Occ(10.0, 1e-3) if calls["n"] % 2 else _Occ(1e-3, 10.0))

    with pytest.raises(RefineOscillationError):
        refine_with_simulator(g, cluster, occupancy_fn=flip,
                              on_oscillation="raise", max_iters=6)
    # default "best" returns the simulator-best iterate, not converged
    r = refine_with_simulator(g, cluster, occupancy_fn=flip, max_iters=6)
    assert not r.converged and len(r.steps) >= 2
    with pytest.raises(ValueError):
        refine_with_simulator(g, cluster, on_oscillation="bogus")
    with pytest.raises(ValueError):
        refine_with_simulator(g, cluster, rel_tol=-0.1)


def test_refine_rel_tol_accepts_near_stationary():
    g = _toy_chain()
    cluster = stepped(4)
    calls = {"n": 0}

    def drift(plan):
        calls["n"] += 1
        return _Occ(0.5 + 1e-6 * calls["n"], 0.2)   # ~ppm wobble

    r = refine_with_simulator(g, cluster, occupancy_fn=drift,
                              rel_tol=1e-3, max_iters=5)
    assert r.converged and len(r.steps) == 2


def test_refine_failed_sample_keeps_weights_and_never_certifies():
    g = _toy_chain()
    cluster = stepped(4)

    class _Bad(_Occ):
        failures = 2

    r = refine_with_simulator(g, cluster, max_iters=5,
                              occupancy_fn=lambda p: _Bad(0.5, 0.2))
    # the untrusted sample is recorded but cannot move the axis weights,
    # so the same point repeats — and the repeat is NOT a certified
    # fixed point
    assert len(r.steps) == 1 and not r.converged
    assert r.steps[0].beta == 1.0 and r.steps[0].alpha == 1.0
