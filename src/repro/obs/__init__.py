"""Unified observability: span tracing, metrics, flight recorder, logs.

Zero-dependency and off by default — installing nothing costs nothing
(see the zero-overhead contract in :mod:`repro.obs.trace`).  A typical
instrumented session:

    from repro import obs

    tracer = obs.set_tracer(obs.Tracer())
    metrics = obs.set_metrics(obs.Metrics())
    obs.set_postmortem_dir("artifacts/")
    ...  # run planner / mesh executor / refinement
    obs.write_trace("trace.json", tracer)
    metrics.export("metrics.json")
    obs.set_tracer(None); obs.set_metrics(None)

Submodules: :mod:`.trace` (spans + Perfetto export), :mod:`.metrics`
(counters/gauges/histograms), :mod:`.flight` (ring buffer +
postmortems), :mod:`.log` (``REPRO_LOG``-gated structured lines),
:mod:`.skew` (measured-vs-simulated comparisons).
"""
from .flight import (FlightRecorder, dump_postmortem, get_flight,
                     postmortem_dir, set_postmortem_dir)
from .log import log
from .metrics import Metrics, get_metrics, set_metrics
from .skew import diff_traces, stage_skew
from .trace import (CONTROL_TRACK, NULL_SPAN, PLANNER_TRACK, STAGE_CAT,
                    Tracer, device_track, get_tracer, link_track,
                    load_trace, set_tracer, span, span_events,
                    write_trace)

__all__ = [
    "CONTROL_TRACK", "NULL_SPAN", "PLANNER_TRACK", "STAGE_CAT",
    "FlightRecorder", "Metrics", "Tracer",
    "device_track", "diff_traces", "dump_postmortem", "get_flight",
    "get_metrics", "get_tracer", "link_track", "load_trace", "log",
    "postmortem_dir", "set_metrics", "set_postmortem_dir", "set_tracer",
    "span", "span_events", "stage_skew", "write_trace",
]
