"""§4 metric — DPP search time per benchmark model, batched vs the scalar
reference, for both estimators, plus optimality confirmation vs exhaustive
search on a small graph.

``run(json_path=...)`` additionally writes ``BENCH_search.json`` with the
per-model search microseconds, estimator row/call counts and speedups, so
CI can track the planner's perf trajectory across PRs.  The harness
*asserts* (a) batched == reference plan and cost on every model and (b)
DP matches the exhaustive optimum — a benchmark that silently drifted
away from exactness would be meaningless.
"""
from __future__ import annotations

import json
import random
import sys

from repro.core import GBDTEstimator, Testbed
from repro.core.dpp import plan_search, plan_search_reference
from repro.core.exhaustive import exhaustive_search
from repro.core.graph import ConvT, LayerSpec, chain
from repro.configs.edge_models import EDGE_MODELS
from repro.sim import TraceConfig, train_estimators

from .common import EST, emit, json_arg, time_call

#: trace/tree budget for the in-benchmark GBDT (small on purpose: the
#: speedup under test is planner call overhead, not model quality)
_GBDT_SAMPLES = 2500
_GBDT_TREES = 40


def _bench_model(model: str, g, est_batched, make_ref_est, tb) -> dict:
    # same best-of-3 policy on both sides so the speedup is comparable;
    # make_ref_est() runs inside the timed call on purpose — a fresh
    # estimator per repeat keeps the reference's scalar caches cold
    us_b, res = time_call(lambda: plan_search(g, est_batched, tb))
    us_r, ref = time_call(
        lambda: plan_search_reference(g, make_ref_est(), tb))
    match = res.plan == ref.plan and res.cost == ref.cost
    assert match, (f"{model}: batched plan_search diverged from reference "
                   f"(costs {res.cost} vs {ref.cost})")
    return {
        "layers": len(g),
        "batched_us": round(us_b, 1),
        "reference_us": round(us_r, 1),
        "speedup": round(us_r / max(us_b, 1e-9), 2),
        "match": match,
        "i_rows": res.stats.i_calls,
        "s_rows": res.stats.s_calls,
        "ref_i_calls": ref.stats.i_calls,
        "ref_s_calls": ref.stats.s_calls,
    }


def run(json_path: str | None = None) -> dict:
    tb = Testbed(nodes=4, bandwidth_gbps=1.0)
    out: dict = {"testbed": {"nodes": tb.nodes,
                             "bandwidth_gbps": tb.bandwidth_gbps},
                 "gbdt": {"n_samples": _GBDT_SAMPLES, "trees": _GBDT_TREES},
                 "models": {}}

    for model, fn in EDGE_MODELS.items():
        g = fn()
        rec = _bench_model(model, g, EST, lambda: EST, tb)
        out["models"][model] = {"analytic": rec}
        emit(f"search/{model}", rec["batched_us"],
             f"layers={rec['layers']};i_rows={rec['i_rows']};"
             f"s_rows={rec['s_rows']};speedup_vs_reference="
             f"{rec['speedup']:.1f}x;match={rec['match']}")

    # data-driven CE: the reference walks the forest once per scalar call,
    # the batched path twice per search — this is the headline speedup.
    # Fresh GBDTEstimator per reference repeat keeps its caches cold.
    gbdt = train_estimators(
        TraceConfig(n_samples=_GBDT_SAMPLES, seed=0),
        gbdt_kwargs=dict(n_estimators=_GBDT_TREES, max_depth=6))
    for model, fn in EDGE_MODELS.items():
        g = fn()
        rec = _bench_model(
            model, g, gbdt,
            lambda: GBDTEstimator(gbdt.i_model, gbdt.s_model), tb)
        out["models"][model]["gbdt"] = rec
        emit(f"search-gbdt/{model}", rec["batched_us"],
             f"speedup_vs_reference={rec['speedup']:.1f}x;"
             f"match={rec['match']}")

    # optimality check vs exhaustive on a 5-layer random graph — DP must
    # find the oracle optimum AND beat it on wall clock
    rng = random.Random(0)
    layers = []
    h, c = 28, 32
    for i in range(5):
        layers.append(LayerSpec(f"l{i}", ConvT.CONV, h, h, c, c, 3, 1, 1))
    g = chain("opt5", layers)
    us_dp, dp = time_call(lambda: plan_search(g, EST, tb))
    us_ex, ex = time_call(lambda: exhaustive_search(g, EST, tb), repeats=1)
    match = abs(dp.cost - ex[1]) < 1e-12
    assert match, f"DP missed the exhaustive optimum: {dp.cost} vs {ex[1]}"
    assert us_dp < us_ex, (f"DP ({us_dp:.0f}us) did not beat exhaustive "
                           f"({us_ex:.0f}us)")
    out["optimality_5layer"] = {
        "dp_cost_ms": dp.cost * 1e3, "exhaustive_cost_ms": ex[1] * 1e3,
        "match": match,
        "speedup_vs_exhaustive": round(us_ex / max(us_dp, 1e-9), 1)}
    emit("search/optimality-5layer", us_dp,
         f"dp={dp.cost * 1e3:.4f}ms;exhaustive={ex[1] * 1e3:.4f}ms;"
         f"match={match};"
         f"speedup_vs_exhaustive={us_ex / max(us_dp, 1e-9):.1f}x")

    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
        print(f"# wrote {json_path}", file=sys.stderr)
    return out


if __name__ == "__main__":
    run(json_path=json_arg(sys.argv[1:]))
